//! Deterministic, seeded fault injection for chaos testing.
//!
//! A [`FaultPlan`] is the process-wide schedule: which failpoints fire
//! (probabilities), how hard (delay/stall durations), and how often (a
//! shared budget of *injected failures*). One plan is shared by every
//! endpoint of a fabric; each endpoint derives a [`FaultInjector`] whose
//! PRNG stream is keyed by its rank, so a given `(seed, nranks)` pair
//! replays the same fault schedule run after run regardless of thread
//! interleaving.
//!
//! The subsystem mirrors the `SPDNN_TRACE` contract from the flight
//! recorder: [`from_env`] parses `SPDNN_FAULT` exactly once into a
//! process-wide plan ([`None`] when unset), and a dormant plan costs the
//! hot path one `Option` branch per failpoint site — no clock reads, no
//! PRNG draws, no checksum arithmetic.
//!
//! Failure semantics are split between *free* rolls (delays, which
//! perturb timing but cannot fail a request and are excluded from the
//! budget) and *fault* rolls (drop / bit-flip / panic / stall, each of
//! which consumes one unit of budget before firing). The budget is what
//! lets the chaos CLI assert `respawns <= injected`: every generation
//! loss traces back to exactly one consumed fault.

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use crate::util::Rng;

/// Stream-mixing constant (golden ratio) for deriving per-rank seeds.
const STREAM_MIX: u64 = 0x9E3779B97F4A7C15;

/// The fault schedule: per-failpoint probabilities, durations, and the
/// shared failure budget. All-zero probabilities (the [`Default`]) make
/// every failpoint inert even when a plan is installed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    /// Base seed; each injector stream mixes in its rank.
    pub seed: u64,
    /// Per-message probability of an injected send/recv delay (free).
    pub delay_p: f64,
    /// Duration of one injected message delay, microseconds.
    pub delay_us: u64,
    /// Per-message probability of dropping a send and poisoning (fault).
    pub drop_p: f64,
    /// Per-payload probability of a wire bit-flip (fault).
    pub flip_p: f64,
    /// Per-job probability of a rank compute panic (fault).
    pub panic_p: f64,
    /// Per-job probability of a rank compute stall (fault).
    pub stall_p: f64,
    /// Duration of one injected stall, milliseconds.
    pub stall_ms: u64,
    /// Duration of one injected scheduler dispatch delay, microseconds
    /// (rolled with `delay_p`; free).
    pub dispatch_delay_us: u64,
    /// Fabric stall-watchdog deadline, milliseconds; 0 = no watchdog.
    pub watchdog_ms: u64,
    /// Maximum number of budgeted faults the plan may inject.
    pub budget: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            seed: 42,
            delay_p: 0.0,
            delay_us: 200,
            drop_p: 0.0,
            flip_p: 0.0,
            panic_p: 0.0,
            stall_p: 0.0,
            stall_ms: 400,
            dispatch_delay_us: 100,
            watchdog_ms: 0,
            budget: u64::MAX,
        }
    }
}

impl FaultSpec {
    /// The `SPDNN_FAULT=1` preset: a little of everything, a watchdog
    /// short enough to beat the injected stalls, and a small budget.
    pub fn chaos() -> Self {
        FaultSpec {
            delay_p: 0.02,
            drop_p: 0.005,
            flip_p: 0.005,
            panic_p: 0.005,
            stall_p: 0.002,
            watchdog_ms: 150,
            budget: 8,
            ..FaultSpec::default()
        }
    }

    /// Parse the `SPDNN_FAULT` key=value grammar (comma- or
    /// space-separated): `seed`, `delay`, `delay_us`, `drop`, `flip`,
    /// `panic`, `stall`, `stall_ms`, `dispatch_delay_us`, `watchdog_ms`,
    /// `budget`. Probability keys take floats in `[0, 1]`; the rest take
    /// unsigned integers. Unknown keys or unparsable values reject the
    /// whole string ([`None`]), matching `SPDNN_TRACE`'s parse-or-off
    /// stance.
    pub fn parse(s: &str) -> Option<Self> {
        let mut spec = FaultSpec::default();
        for pair in s.split([',', ' ']).filter(|p| !p.is_empty()) {
            let (key, value) = pair.split_once('=')?;
            match key {
                "seed" => spec.seed = value.parse().ok()?,
                "delay" => spec.delay_p = parse_p(value)?,
                "delay_us" => spec.delay_us = value.parse().ok()?,
                "drop" => spec.drop_p = parse_p(value)?,
                "flip" => spec.flip_p = parse_p(value)?,
                "panic" => spec.panic_p = parse_p(value)?,
                "stall" => spec.stall_p = parse_p(value)?,
                "stall_ms" => spec.stall_ms = value.parse().ok()?,
                "dispatch_delay_us" => spec.dispatch_delay_us = value.parse().ok()?,
                "watchdog_ms" => spec.watchdog_ms = value.parse().ok()?,
                "budget" => spec.budget = value.parse().ok()?,
                _ => return None,
            }
        }
        Some(spec)
    }

    /// The stall-watchdog deadline, or [`None`] when disabled.
    pub fn watchdog(&self) -> Option<Duration> {
        (self.watchdog_ms > 0).then(|| Duration::from_millis(self.watchdog_ms))
    }
}

fn parse_p(value: &str) -> Option<f64> {
    let p: f64 = value.parse().ok()?;
    (0.0..=1.0).contains(&p).then_some(p)
}

/// A shared, armed fault schedule: the [`FaultSpec`] plus the live
/// budget counter. Share one plan (via `Arc`) across every endpoint of
/// a fabric and its pool scheduler.
#[derive(Debug)]
pub struct FaultPlan {
    spec: FaultSpec,
    injected: AtomicU64,
    armed: AtomicBool,
}

impl FaultPlan {
    /// An armed plan for `spec`.
    pub fn new(spec: FaultSpec) -> Arc<Self> {
        Arc::new(FaultPlan {
            spec,
            injected: AtomicU64::new(0),
            armed: AtomicBool::new(true),
        })
    }

    /// The schedule this plan runs.
    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Budgeted faults injected so far (drops, flips, panics, stalls —
    /// not delays).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// True while failpoints may fire.
    pub fn armed(&self) -> bool {
        self.armed.load(Ordering::Relaxed)
    }

    /// Stop all failpoints (the "faults stop" phase of a chaos run).
    /// Delays stop too; the injected counter is preserved.
    pub fn disarm(&self) {
        self.armed.store(false, Ordering::Relaxed);
    }

    /// Re-enable failpoints after [`FaultPlan::disarm`].
    pub fn rearm(&self) {
        self.armed.store(true, Ordering::Relaxed);
    }

    /// Consume one unit of budget; false once the budget is spent.
    fn consume(&self) -> bool {
        let budget = self.spec.budget;
        self.injected
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| {
                (n < budget).then_some(n + 1)
            })
            .is_ok()
    }
}

/// The process-wide plan from the `SPDNN_FAULT` environment variable,
/// parsed once: unset/`0`/`off` → [`None`]; `1`/`on` →
/// [`FaultSpec::chaos`]; anything else is the key=value grammar of
/// [`FaultSpec::parse`] (parse failure → [`None`]).
pub fn from_env() -> Option<Arc<FaultPlan>> {
    static PLAN: OnceLock<Option<Arc<FaultPlan>>> = OnceLock::new();
    PLAN.get_or_init(|| match std::env::var("SPDNN_FAULT").ok().as_deref() {
        None | Some("") | Some("0") | Some("off") => None,
        Some("1") | Some("on") => Some(FaultPlan::new(FaultSpec::chaos())),
        Some(s) => FaultSpec::parse(s).map(FaultPlan::new),
    })
    .clone()
}

/// One endpoint's deterministic view of a [`FaultPlan`]: a private PRNG
/// stream keyed by the endpoint's rank, so each rank draws an
/// independent, replayable sequence no matter how threads interleave.
#[derive(Debug)]
pub struct FaultInjector {
    plan: Arc<FaultPlan>,
    rng: Rng,
}

impl FaultInjector {
    /// An injector for stream `stream` (rank index; the pool scheduler
    /// uses `nranks`, its observer slot).
    pub fn new(plan: Arc<FaultPlan>, stream: u64) -> Self {
        let seed = plan.spec.seed ^ stream.wrapping_mul(STREAM_MIX);
        FaultInjector {
            plan,
            rng: Rng::new(seed),
        }
    }

    /// The schedule behind this injector.
    pub fn spec(&self) -> &FaultSpec {
        self.plan.spec()
    }

    /// The shared plan behind this injector.
    pub fn plan(&self) -> &Arc<FaultPlan> {
        &self.plan
    }

    /// Bernoulli(`p`) draw for a *free* failpoint (delays): no budget.
    /// `p <= 0` skips the draw so inert failpoints don't advance the
    /// stream.
    pub fn roll_free(&mut self, p: f64) -> bool {
        p > 0.0 && self.plan.armed() && self.rng.gen_bool(p)
    }

    /// Bernoulli(`p`) draw for a *budgeted* fault (drop / flip / panic /
    /// stall): fires only while budget remains, and consumes one unit
    /// when it does.
    pub fn roll_fault(&mut self, p: f64) -> bool {
        self.roll_free(p) && self.plan.consume()
    }

    /// Uniform in `[0, n)` from this injector's stream (bit/word picks
    /// for the flip failpoint).
    pub fn gen_range(&mut self, n: usize) -> usize {
        self.rng.gen_range(n)
    }
}

/// Typed root causes raised by the recovery layers. Each renders to a
/// distinct panic message that the pool's failure triage treats as a
/// *root cause* (none of them match the secondary-failure patterns
/// `"fabric poisoned"` / `"peer rank hung up"`), and that the
/// [`is_stall`]/[`is_corrupt`] classifiers recover on the far side of a
/// `catch_unwind`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultCause {
    /// A watchdog deadline expired while waiting for a peer payload.
    Stall {
        /// Rank whose wait timed out.
        rank: u32,
        /// How long it waited, milliseconds.
        waited_ms: u64,
        /// What it was waiting for (layer/phase/peers).
        wanted: String,
    },
    /// A wire payload failed its checksum at decode.
    Corrupt {
        /// Rank that detected the mismatch.
        rank: u32,
        /// Codec label of the corrupted payload.
        codec: String,
        /// Wire length of the corrupted payload, words.
        words: usize,
    },
    /// An injected compute panic.
    ComputePanic {
        /// Rank that panicked.
        rank: u32,
    },
    /// An injected message drop (the sender poisons after dropping).
    DroppedSend {
        /// Rank that dropped the message.
        rank: u32,
        /// Destination rank.
        to: usize,
        /// What was dropped (layer/phase).
        wanted: String,
    },
}

impl fmt::Display for FaultCause {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultCause::Stall {
                rank,
                waited_ms,
                wanted,
            } => write!(
                f,
                "stall watchdog: rank {rank} waited {waited_ms} ms for {wanted}"
            ),
            FaultCause::Corrupt { rank, codec, words } => write!(
                f,
                "payload corrupt: checksum mismatch on rank {rank} decoding {codec} wire \
                 ({words} words)"
            ),
            FaultCause::ComputePanic { rank } => {
                write!(f, "fault injected: compute panic on rank {rank}")
            }
            FaultCause::DroppedSend { rank, to, wanted } => write!(
                f,
                "fault injected: rank {rank} dropped send to rank {to} ({wanted})"
            ),
        }
    }
}

/// True when a failure message is a stall-watchdog trip.
pub fn is_stall(message: &str) -> bool {
    message.contains("stall watchdog")
}

/// True when a failure message is a payload-integrity failure.
pub fn is_corrupt(message: &str) -> bool {
    message.contains("checksum mismatch")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::parallel::is_secondary;

    #[test]
    fn parse_full_grammar() {
        let spec = FaultSpec::parse(
            "seed=7,delay=0.1,delay_us=50,drop=0.2,flip=0.3,panic=0.4,stall=0.5,\
             stall_ms=250,dispatch_delay_us=10,watchdog_ms=100,budget=3",
        )
        .expect("full grammar parses");
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.delay_p, 0.1);
        assert_eq!(spec.delay_us, 50);
        assert_eq!(spec.drop_p, 0.2);
        assert_eq!(spec.flip_p, 0.3);
        assert_eq!(spec.panic_p, 0.4);
        assert_eq!(spec.stall_p, 0.5);
        assert_eq!(spec.stall_ms, 250);
        assert_eq!(spec.dispatch_delay_us, 10);
        assert_eq!(spec.watchdog_ms, 100);
        assert_eq!(spec.budget, 3);
        assert_eq!(spec.watchdog(), Some(Duration::from_millis(100)));
    }

    #[test]
    fn parse_accepts_spaces_and_partial_keys() {
        let spec = FaultSpec::parse("panic=0.5 budget=1").expect("parses");
        assert_eq!(spec.panic_p, 0.5);
        assert_eq!(spec.budget, 1);
        assert_eq!(spec.drop_p, 0.0, "unset keys keep defaults");
        assert_eq!(spec.watchdog(), None);
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert_eq!(FaultSpec::parse("bogus=1"), None);
        assert_eq!(FaultSpec::parse("panic"), None);
        assert_eq!(FaultSpec::parse("panic=nope"), None);
        assert_eq!(FaultSpec::parse("panic=1.5"), None, "p out of [0,1]");
        assert_eq!(FaultSpec::parse("seed=-1"), None);
    }

    #[test]
    fn default_spec_is_inert() {
        let plan = FaultPlan::new(FaultSpec::default());
        let mut inj = FaultInjector::new(Arc::clone(&plan), 0);
        for _ in 0..1000 {
            assert!(!inj.roll_fault(inj.spec().panic_p));
            assert!(!inj.roll_free(inj.spec().delay_p));
        }
        assert_eq!(plan.injected(), 0);
    }

    #[test]
    fn budget_bounds_injected_faults() {
        let plan = FaultPlan::new(FaultSpec {
            budget: 3,
            ..FaultSpec::default()
        });
        let mut inj = FaultInjector::new(Arc::clone(&plan), 1);
        let fired: usize = (0..100).filter(|_| inj.roll_fault(1.0)).count();
        assert_eq!(fired, 3, "exactly the budget fires");
        assert_eq!(plan.injected(), 3);
    }

    #[test]
    fn delays_do_not_consume_budget() {
        let plan = FaultPlan::new(FaultSpec {
            budget: 1,
            ..FaultSpec::default()
        });
        let mut inj = FaultInjector::new(Arc::clone(&plan), 2);
        let delays: usize = (0..50).filter(|_| inj.roll_free(1.0)).count();
        assert_eq!(delays, 50);
        assert_eq!(plan.injected(), 0);
        assert!(inj.roll_fault(1.0), "budget still available for a fault");
    }

    #[test]
    fn streams_are_deterministic_and_distinct() {
        let spec = FaultSpec {
            seed: 99,
            ..FaultSpec::default()
        };
        let draws = |stream: u64| -> Vec<bool> {
            let mut inj = FaultInjector::new(FaultPlan::new(spec), stream);
            (0..64).map(|_| inj.roll_free(0.5)).collect()
        };
        assert_eq!(draws(0), draws(0), "same stream replays identically");
        assert_ne!(draws(0), draws(1), "streams are independent");
    }

    #[test]
    fn disarm_stops_failpoints() {
        let plan = FaultPlan::new(FaultSpec::default());
        let mut inj = FaultInjector::new(Arc::clone(&plan), 0);
        plan.disarm();
        assert!(!inj.roll_fault(1.0));
        assert!(!inj.roll_free(1.0));
        plan.rearm();
        assert!(inj.roll_fault(1.0));
    }

    #[test]
    fn causes_render_as_root_causes() {
        let causes = [
            FaultCause::Stall {
                rank: 2,
                waited_ms: 150,
                wanted: "layer 3 Fwd (from [0, 1])".into(),
            },
            FaultCause::Corrupt {
                rank: 1,
                codec: "f16".into(),
                words: 52,
            },
            FaultCause::ComputePanic { rank: 0 },
            FaultCause::DroppedSend {
                rank: 3,
                to: 0,
                wanted: "layer 1 Fwd".into(),
            },
        ];
        for cause in &causes {
            let msg = cause.to_string();
            assert!(
                !is_secondary(&msg),
                "cause must triage as a root cause: {msg}"
            );
        }
        assert!(is_stall(&causes[0].to_string()));
        assert!(!is_corrupt(&causes[0].to_string()));
        assert!(is_corrupt(&causes[1].to_string()));
        assert!(!is_stall(&causes[1].to_string()));
        assert!(!is_stall(&causes[2].to_string()));
        assert!(!is_corrupt(&causes[3].to_string()));
    }
}
