//! Execution runtimes for the live hot path.
//!
//! - [`parallel`]: the shared-memory rank-parallel engine — one OS thread
//!   per rank over the simulated fabric, with panic-to-error rank
//!   lifecycle management and per-rank timer aggregation. Always built.
//! - [`fault`]: the deterministic chaos engine — a seeded, budgeted
//!   fault schedule (`SPDNN_FAULT`) whose failpoints are threaded
//!   through the fabric, the rank compute loop, and the pool scheduler.
//!   Always built; dormant failpoints cost one branch each.
//! - `engine`/`pjrt` (feature `pjrt`): load the AOT artifacts (HLO text,
//!   produced once by `python/compile/aot.py`) and execute them on the XLA
//!   CPU client, with Python never on the request path. The feature
//!   compiles everywhere against the vendored [`xla_stub`] API stand-in
//!   (so `cargo build --all-features` works in CI); actually *executing*
//!   HLO additionally requires vendoring the real `xla` crate — see
//!   `xla_stub.rs` for the swap instructions.

pub mod fault;
pub mod parallel;

#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub mod xla_stub;

#[cfg(feature = "pjrt")]
pub use engine::PjrtLayerEngine;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtRuntime;

pub use fault::{FaultCause, FaultInjector, FaultPlan, FaultSpec};
pub use parallel::{
    run_groups, run_ranks, FaultScope, GroupFailure, GroupRun, ParallelRun, RankFailure,
};

use std::path::PathBuf;

/// Locate the artifacts directory: `$SPDNN_ARTIFACTS` or `./artifacts`
/// relative to the workspace root.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("SPDNN_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // walk up from cwd looking for an `artifacts/` directory
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// Artifact file name for a forward layer block of shape m×k.
pub fn fwd_artifact(m: usize, k: usize) -> String {
    format!("layer_fwd_{m}x{k}.hlo.txt")
}

/// Artifact file name for a backward layer block of shape m×k.
pub fn bwd_artifact(m: usize, k: usize) -> String {
    format!("layer_bwd_{m}x{k}.hlo.txt")
}

/// Artifact file name for a batched forward block m×k×b.
pub fn fwd_batch_artifact(m: usize, k: usize, b: usize) -> String {
    format!("layer_fwd_batch_{m}x{k}x{b}.hlo.txt")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names() {
        assert_eq!(fwd_artifact(64, 256), "layer_fwd_64x256.hlo.txt");
        assert_eq!(bwd_artifact(8, 16), "layer_bwd_8x16.hlo.txt");
        assert_eq!(
            fwd_batch_artifact(64, 256, 16),
            "layer_fwd_batch_64x256x16.hlo.txt"
        );
    }

    #[test]
    fn artifacts_dir_env_override() {
        std::env::set_var("SPDNN_ARTIFACTS", "/tmp/spdnn_artifacts_test");
        assert_eq!(
            artifacts_dir(),
            PathBuf::from("/tmp/spdnn_artifacts_test")
        );
        std::env::remove_var("SPDNN_ARTIFACTS");
    }
}
