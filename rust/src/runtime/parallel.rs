//! Shared-memory parallel execution engine: one OS thread per rank over the
//! simulated message-passing fabric.
//!
//! This is the single place that owns rank lifecycles for live runs. Every
//! distributed driver (SGD, minibatch SpMM, batched inference serving)
//! hands the engine a per-rank worker closure; the engine
//! - builds the fabric and spawns one scoped thread per rank,
//! - converts rank panics into [`RankFailure`] errors instead of aborting
//!   the process, poisoning the fabric so peers blocked in `recv` unwind
//!   rather than deadlock,
//! - enforces the end-of-run invariant that no rank leaves unconsumed
//!   messages in its stash,
//! - collects per-rank fabric counters and aggregates per-rank
//!   [`PhaseTimer`]s for live breakdown reporting.

use crate::comm::{fabric, fabric_with, Endpoint, FabricStats};
use crate::runtime::fault;
use crate::util::PhaseTimer;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// A rank failed (panicked, or violated a fabric invariant).
#[derive(Debug, Clone)]
pub struct RankFailure {
    pub rank: usize,
    pub message: String,
}

impl std::fmt::Display for RankFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "rank {} failed: {}", self.rank, self.message)
    }
}

/// Result of a successful engine run: per-rank worker outputs (in rank
/// order) plus the per-rank fabric counters.
pub struct ParallelRun<T> {
    pub outputs: Vec<T>,
    /// Per-rank (words, messages) sent over the fabric.
    pub sent: Vec<(u64, u64)>,
    /// Full per-rank endpoint counters (aggregate send/recv plus the
    /// per-peer breakdown) — feed these to
    /// [`crate::obs::MetricsRegistry::record_fabric`].
    pub fabric: Vec<FabricStats>,
}

impl<T> ParallelRun<T> {
    /// Sum the per-rank phase timers into one live breakdown — the
    /// engine-owned aggregation point for SpMV / Updt / Comm reporting.
    pub fn merged_timer<'a, F>(&'a self, timer_of: F) -> PhaseTimer
    where
        F: Fn(&'a T) -> &'a PhaseTimer,
    {
        let mut merged = PhaseTimer::new();
        for out in &self.outputs {
            merged.merge(timer_of(out));
        }
        merged
    }
}

/// Run `worker(rank, endpoint)` on `nparts` concurrent OS threads over a
/// fresh fully-connected fabric. Returns the outputs in rank order, or the
/// most informative [`RankFailure`] if any rank failed.
pub fn run_ranks<T, F>(nparts: usize, worker: F) -> Result<ParallelRun<T>, RankFailure>
where
    T: Send,
    F: Fn(usize, &mut Endpoint) -> T + Sync,
{
    assert!(nparts > 0, "need at least one rank");
    let endpoints = fabric(nparts);

    let results: Vec<Result<(T, FabricStats), String>> = std::thread::scope(|scope| {
        let worker = &worker;
        let handles: Vec<_> = endpoints
            .into_iter()
            .enumerate()
            .map(|(rank, mut ep)| {
                scope.spawn(move || {
                    let out = catch_unwind(AssertUnwindSafe(|| worker(rank, &mut ep)));
                    match out {
                        Ok(value) => {
                            if ep.drained() {
                                Ok((value, ep.stats()))
                            } else {
                                ep.poison();
                                Err("unconsumed messages left in stash".to_string())
                            }
                        }
                        Err(payload) => {
                            ep.poison();
                            Err(panic_message(&payload))
                        }
                    }
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|p| Err(panic_message(&p))))
            .collect()
    });

    let mut outputs = Vec::with_capacity(nparts);
    let mut sent = Vec::with_capacity(nparts);
    let mut stats = Vec::with_capacity(nparts);
    let mut failure: Option<RankFailure> = None;
    for (rank, result) in results.into_iter().enumerate() {
        match result {
            Ok((value, st)) => {
                outputs.push(value);
                sent.push((st.sent_words, st.sent_msgs));
                stats.push(st);
            }
            Err(message) => {
                // Prefer the root cause over the secondary unwinds of
                // ranks that were merely blocked on (or sending to) the
                // rank that actually failed.
                let candidate = RankFailure { rank, message };
                let better = match &failure {
                    None => true,
                    Some(cur) => {
                        is_secondary(&cur.message) && !is_secondary(&candidate.message)
                    }
                };
                if better {
                    failure = Some(candidate);
                }
            }
        }
    }
    match failure {
        Some(f) => Err(f),
        None => Ok(ParallelRun {
            outputs,
            sent,
            fabric: stats,
        }),
    }
}

/// Which fabrics of a replica-group run the process-wide `SPDNN_FAULT`
/// chaos plan arms (see [`run_groups`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultScope {
    /// The env plan arms every fabric — intra-group and inter-group alike
    /// (the default, matching [`run_ranks`]'s behavior for one group).
    Env,
    /// The env plan arms only this group's intra-group fabric. The other
    /// groups and the inter-group rings stay injector-free but keep the
    /// plan's stall watchdog, so a fault in the scoped group surfaces as
    /// a typed failure instead of hanging its all-reduce partners.
    Group(usize),
    /// No fault plan anywhere, regardless of the environment.
    Off,
}

/// A rank of a replica-group run failed.
#[derive(Debug, Clone)]
pub struct GroupFailure {
    pub group: usize,
    pub rank: usize,
    pub message: String,
}

impl std::fmt::Display for GroupFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "group {} rank {} failed: {}",
            self.group, self.rank, self.message
        )
    }
}

/// Result of a successful [`run_groups`] run: worker outputs and fabric
/// counters indexed `[group][rank]`, for both fabric levels.
pub struct GroupRun<T> {
    pub outputs: Vec<Vec<T>>,
    /// Per-thread counters of the intra-group (model-parallel) fabrics.
    pub intra: Vec<Vec<FabricStats>>,
    /// Per-thread counters of the inter-group (data-parallel ring)
    /// fabrics — the gradient all-reduce traffic, and nothing else.
    pub inter: Vec<Vec<FabricStats>>,
}

impl<T> GroupRun<T> {
    /// Sum every thread's phase timer into one live breakdown.
    pub fn merged_timer<'a, F>(&'a self, timer_of: F) -> PhaseTimer
    where
        F: Fn(&'a T) -> &'a PhaseTimer,
    {
        let mut merged = PhaseTimer::new();
        for grp in &self.outputs {
            for out in grp {
                merged.merge(timer_of(out));
            }
        }
        merged
    }
}

/// Run `worker(group, rank, intra, inter)` on `groups × nranks` concurrent
/// OS threads over a **two-level fabric**: each group owns a private
/// fully-connected intra-group fabric of `nranks` endpoints (the existing
/// model-parallel engines run here unchanged), and each rank index `j`
/// owns a fully-connected inter-group fabric of `groups` endpoints linking
/// thread `(g, j)` to its same-rank peers in every other group — the
/// replica gradient all-reduce runs there, with `inter.rank == g`.
///
/// Failure semantics extend [`run_ranks`]: a panicking thread poisons
/// **both** of its fabrics, so model-parallel peers in its own group and
/// all-reduce partners in other groups unwind instead of deadlocking; the
/// most informative (non-secondary) failure wins the triage. `scope`
/// controls which fabrics the chaos plan arms, so a fault campaign can be
/// confined to one replica group while the rest of the job stays clean.
pub fn run_groups<T, F>(
    groups: usize,
    nranks: usize,
    scope: FaultScope,
    worker: F,
) -> Result<GroupRun<T>, GroupFailure>
where
    T: Send,
    F: Fn(usize, usize, &mut Endpoint, &mut Endpoint) -> T + Sync,
{
    assert!(groups > 0, "need at least one replica group");
    assert!(nranks > 0, "need at least one rank per group");
    let plan = match scope {
        FaultScope::Off => None,
        _ => fault::from_env(),
    };
    let watchdog = plan.as_ref().and_then(|p| p.spec().watchdog());

    let intra_fabrics: Vec<Vec<Endpoint>> = (0..groups)
        .map(|g| {
            let armed = match scope {
                FaultScope::Env => plan.clone(),
                FaultScope::Group(t) if t == g => plan.clone(),
                _ => None,
            };
            fabric_with(nranks, armed, watchdog)
        })
        .collect();
    let inter_fabrics: Vec<Vec<Endpoint>> = (0..nranks)
        .map(|_| {
            let armed = match scope {
                FaultScope::Env => plan.clone(),
                _ => None,
            };
            fabric_with(groups, armed, watchdog)
        })
        .collect();

    // Pair each thread's endpoints: intra rank `j` of group `g`'s fabric,
    // inter rank `g` of ring `j`'s fabric.
    let mut inter_slots: Vec<Vec<Option<Endpoint>>> = inter_fabrics
        .into_iter()
        .map(|f| f.into_iter().map(Some).collect())
        .collect();
    let mut work = Vec::with_capacity(groups * nranks);
    for (g, geps) in intra_fabrics.into_iter().enumerate() {
        for (j, iep) in geps.into_iter().enumerate() {
            let xep = inter_slots[j][g].take().expect("endpoint paired once");
            work.push((g, j, iep, xep));
        }
    }

    type ThreadResult<T> = Result<(T, FabricStats, FabricStats), String>;
    let results: Vec<(usize, usize, ThreadResult<T>)> = std::thread::scope(|sc| {
        let worker = &worker;
        let handles: Vec<_> = work
            .into_iter()
            .map(|(g, j, mut iep, mut xep)| {
                let h = sc.spawn(move || {
                    let out = catch_unwind(AssertUnwindSafe(|| worker(g, j, &mut iep, &mut xep)));
                    match out {
                        Ok(value) => {
                            if iep.drained() && xep.drained() {
                                Ok((value, iep.stats(), xep.stats()))
                            } else {
                                iep.poison();
                                xep.poison();
                                Err("unconsumed messages left in stash".to_string())
                            }
                        }
                        Err(payload) => {
                            iep.poison();
                            xep.poison();
                            Err(panic_message(&payload))
                        }
                    }
                });
                (g, j, h)
            })
            .collect();
        handles
            .into_iter()
            .map(|(g, j, h)| (g, j, h.join().unwrap_or_else(|p| Err(panic_message(&p)))))
            .collect()
    });

    let mut outputs: Vec<Vec<T>> = (0..groups).map(|_| Vec::with_capacity(nranks)).collect();
    let mut intra: Vec<Vec<FabricStats>> =
        (0..groups).map(|_| Vec::with_capacity(nranks)).collect();
    let mut inter: Vec<Vec<FabricStats>> =
        (0..groups).map(|_| Vec::with_capacity(nranks)).collect();
    let mut failure: Option<GroupFailure> = None;
    for (g, j, result) in results {
        match result {
            Ok((value, ist, xst)) => {
                // results arrive in (g, j) spawn order, so pushes keep
                // rank order within each group
                outputs[g].push(value);
                intra[g].push(ist);
                inter[g].push(xst);
            }
            Err(message) => {
                let candidate = GroupFailure {
                    group: g,
                    rank: j,
                    message,
                };
                let better = match &failure {
                    None => true,
                    Some(cur) => is_secondary(&cur.message) && !is_secondary(&candidate.message),
                };
                if better {
                    failure = Some(candidate);
                }
            }
        }
    }
    match failure {
        Some(f) => Err(f),
        None => Ok(GroupRun {
            outputs,
            intra,
            inter,
        }),
    }
}

/// True for failure messages that are consequences of another rank dying
/// (blocked receivers woken by poisoning, sends to a hung-up peer) rather
/// than root causes. Shared with the serving pool's failure triage.
pub(crate) fn is_secondary(message: &str) -> bool {
    message.contains("fabric poisoned") || message.contains("peer rank hung up")
}

pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic>".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Phase;

    #[test]
    fn all_to_all_sum_and_counters() {
        let n = 6usize;
        let run = run_ranks(n, |rank, ep| {
            let me = rank as u32;
            for to in 0..n as u32 {
                if to != me {
                    ep.send(to, 0, Phase::Forward, me, vec![me as f32]);
                }
            }
            let mut sum = 0.0f32;
            for from in 0..n as u32 {
                if from != me {
                    sum += ep.recv(from, 0, Phase::Forward, from)[0];
                }
            }
            sum
        })
        .expect("run succeeds");
        let all: f32 = (0..n as u32).map(|x| x as f32).sum();
        for (rank, &sum) in run.outputs.iter().enumerate() {
            assert_eq!(sum, all - rank as f32, "rank {rank}");
        }
        for &(words, msgs) in &run.sent {
            assert_eq!(words, (n - 1) as u64);
            assert_eq!(msgs, (n - 1) as u64);
        }
        for st in &run.fabric {
            assert_eq!(st.sent_msgs, (n - 1) as u64);
            assert_eq!(st.recv_msgs, (n - 1) as u64);
            assert_eq!(st.peers.len(), n);
            let peer_sent: u64 = st.peers.iter().map(|p| p.sent_msgs).sum();
            assert_eq!(peer_sent, st.sent_msgs);
        }
    }

    #[test]
    fn rank_panic_becomes_error_without_deadlock() {
        // Rank 0 panics before sending; ranks 1..3 block on receives from
        // it and must unwind via fabric poisoning instead of hanging.
        let err = run_ranks(4, |rank, ep| {
            if rank == 0 {
                panic!("injected failure on rank 0");
            }
            ep.recv(0, 0, Phase::Forward, 0);
        })
        .expect_err("run must fail");
        assert_eq!(err.rank, 0);
        assert!(
            err.message.contains("injected failure"),
            "root cause lost: {}",
            err.message
        );
    }

    #[test]
    fn send_to_dead_rank_does_not_mask_root_cause() {
        // Rank 3 dies; rank 1 later sends to it and panics with the
        // secondary "peer rank hung up" — the reported failure must still
        // be rank 3's own panic.
        let err = run_ranks(4, |rank, ep| match rank {
            3 => panic!("rank 3 exploded"),
            1 => {
                std::thread::sleep(std::time::Duration::from_millis(150));
                ep.send(3, 0, Phase::Forward, 0, vec![1.0]);
            }
            _ => {}
        })
        .expect_err("engine must surface the failure");
        assert_eq!(err.rank, 3, "masked by: {}", err.message);
        assert!(err.message.contains("exploded"), "{}", err.message);
    }

    #[test]
    fn unreceived_channel_message_is_an_error() {
        // Rank 0 sends a message rank 1 never receives at all (it stays in
        // the channel, not the stash) — still flagged as a leak. The
        // barrier guarantees the send lands before rank 1 returns.
        let barrier = std::sync::Barrier::new(2);
        let err = run_ranks(2, |rank, ep| {
            if rank == 0 {
                ep.send(1, 0, Phase::Forward, 0, vec![1.0]);
            }
            barrier.wait();
        })
        .expect_err("channel leak must fail");
        assert_eq!(err.rank, 1);
        assert!(err.message.contains("unconsumed"), "{}", err.message);
    }

    #[test]
    fn undrained_stash_is_an_error() {
        // Rank 0 sends two tags; rank 1 consumes only the second, leaving
        // the first stashed — the engine must flag the leak.
        let err = run_ranks(2, |rank, ep| {
            if rank == 0 {
                ep.send(1, 0, Phase::Forward, 0, vec![1.0]);
                ep.send(1, 1, Phase::Forward, 0, vec![2.0]);
            } else {
                assert_eq!(ep.recv(0, 1, Phase::Forward, 0), vec![2.0]);
            }
        })
        .expect_err("stash leak must fail");
        assert_eq!(err.rank, 1);
        assert!(err.message.contains("unconsumed"), "{}", err.message);
    }

    #[test]
    fn timers_aggregate_across_ranks() {
        let run = run_ranks(3, |rank, _ep| {
            let mut t = PhaseTimer::new();
            t.add_secs("spmv", (rank + 1) as f64);
            t
        })
        .expect("run succeeds");
        let merged = run.merged_timer(|t| t);
        assert!((merged.get_secs("spmv") - 6.0).abs() < 1e-9);
    }

    #[test]
    fn outputs_are_in_rank_order() {
        let run = run_ranks(5, |rank, _ep| rank * 10).expect("run succeeds");
        assert_eq!(run.outputs, vec![0, 10, 20, 30, 40]);
    }

    #[test]
    fn groups_run_two_level_traffic() {
        // Each thread (g, j): intra all-to-all within its group, then an
        // inter exchange with the same rank of every other group. The two
        // fabrics are disjoint — same tags on both must not collide.
        let (groups, nranks) = (3usize, 2usize);
        let run = run_groups(groups, nranks, FaultScope::Off, |g, j, intra, inter| {
            assert_eq!(intra.rank as usize, j);
            assert_eq!(inter.rank as usize, g);
            for to in 0..nranks as u32 {
                if to != j as u32 {
                    intra.send(to, 0, Phase::Forward, j as u32, vec![(g * 10 + j) as f32]);
                }
            }
            let mut intra_sum = 0.0f32;
            for from in 0..nranks as u32 {
                if from != j as u32 {
                    intra_sum += intra.recv(from, 0, Phase::Forward, from)[0];
                }
            }
            for to in 0..groups as u32 {
                if to != g as u32 {
                    inter.send(to, 0, Phase::Forward, g as u32, vec![(g * 10 + j) as f32]);
                }
            }
            let mut inter_sum = 0.0f32;
            for from in 0..groups as u32 {
                if from != g as u32 {
                    inter_sum += inter.recv(from, 0, Phase::Forward, from)[0];
                }
            }
            (intra_sum, inter_sum)
        })
        .expect("run succeeds");
        for g in 0..groups {
            for j in 0..nranks {
                let (intra_sum, inter_sum) = run.outputs[g][j];
                // peers within the group share g, differ in j
                let expect_intra: f32 = (0..nranks)
                    .filter(|&x| x != j)
                    .map(|x| (g * 10 + x) as f32)
                    .sum();
                // same-rank peers across groups share j, differ in g
                let expect_inter: f32 = (0..groups)
                    .filter(|&x| x != g)
                    .map(|x| (x * 10 + j) as f32)
                    .sum();
                assert_eq!(intra_sum, expect_intra, "group {g} rank {j}");
                assert_eq!(inter_sum, expect_inter, "group {g} rank {j}");
                assert_eq!(run.intra[g][j].sent_msgs, (nranks - 1) as u64);
                assert_eq!(run.inter[g][j].sent_msgs, (groups - 1) as u64);
            }
        }
    }

    #[test]
    fn group_panic_unblocks_all_reduce_partners() {
        // Thread (0, 0) dies; its group peers block on intra receives and
        // its same-rank partners in other groups block on inter receives.
        // All of them must unwind via poisoning, and triage must surface
        // the root cause with its group and rank.
        let err = run_groups(3, 2, FaultScope::Off, |g, j, intra, inter| {
            if g == 0 && j == 0 {
                panic!("injected failure in group 0");
            }
            if g == 0 {
                intra.recv(0, 0, Phase::Forward, 0);
            } else if j == 0 {
                inter.recv(0, 0, Phase::Forward, 0);
            }
        })
        .expect_err("run must fail");
        assert_eq!((err.group, err.rank), (0, 0));
        assert!(err.message.contains("injected failure"), "{}", err.message);
    }

    #[test]
    fn group_run_with_one_group_matches_run_ranks_shape() {
        let run = run_groups(1, 3, FaultScope::Off, |g, j, _intra, _inter| {
            assert_eq!(g, 0);
            j * 7
        })
        .expect("run succeeds");
        assert_eq!(run.outputs, vec![vec![0, 7, 14]]);
        assert_eq!(run.inter[0].len(), 3);
    }

    #[test]
    fn group_leak_on_either_fabric_is_an_error() {
        // an unconsumed inter-fabric message must be flagged just like an
        // intra-fabric one
        let barrier = std::sync::Barrier::new(4);
        let err = run_groups(2, 2, FaultScope::Off, |g, j, _intra, inter| {
            if g == 0 && j == 1 {
                inter.send(1, 0, Phase::Forward, 0, vec![1.0]);
            }
            barrier.wait();
        })
        .expect_err("leak must fail");
        assert_eq!((err.group, err.rank), (1, 1));
        assert!(err.message.contains("unconsumed"), "{}", err.message);
    }
}
