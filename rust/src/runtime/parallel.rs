//! Shared-memory parallel execution engine: one OS thread per rank over the
//! simulated message-passing fabric.
//!
//! This is the single place that owns rank lifecycles for live runs. Every
//! distributed driver (SGD, minibatch SpMM, batched inference serving)
//! hands the engine a per-rank worker closure; the engine
//! - builds the fabric and spawns one scoped thread per rank,
//! - converts rank panics into [`RankFailure`] errors instead of aborting
//!   the process, poisoning the fabric so peers blocked in `recv` unwind
//!   rather than deadlock,
//! - enforces the end-of-run invariant that no rank leaves unconsumed
//!   messages in its stash,
//! - collects per-rank fabric counters and aggregates per-rank
//!   [`PhaseTimer`]s for live breakdown reporting.

use crate::comm::{fabric, Endpoint, FabricStats};
use crate::util::PhaseTimer;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// A rank failed (panicked, or violated a fabric invariant).
#[derive(Debug, Clone)]
pub struct RankFailure {
    pub rank: usize,
    pub message: String,
}

impl std::fmt::Display for RankFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "rank {} failed: {}", self.rank, self.message)
    }
}

/// Result of a successful engine run: per-rank worker outputs (in rank
/// order) plus the per-rank fabric counters.
pub struct ParallelRun<T> {
    pub outputs: Vec<T>,
    /// Per-rank (words, messages) sent over the fabric.
    pub sent: Vec<(u64, u64)>,
    /// Full per-rank endpoint counters (aggregate send/recv plus the
    /// per-peer breakdown) — feed these to
    /// [`crate::obs::MetricsRegistry::record_fabric`].
    pub fabric: Vec<FabricStats>,
}

impl<T> ParallelRun<T> {
    /// Sum the per-rank phase timers into one live breakdown — the
    /// engine-owned aggregation point for SpMV / Updt / Comm reporting.
    pub fn merged_timer<'a, F>(&'a self, timer_of: F) -> PhaseTimer
    where
        F: Fn(&'a T) -> &'a PhaseTimer,
    {
        let mut merged = PhaseTimer::new();
        for out in &self.outputs {
            merged.merge(timer_of(out));
        }
        merged
    }
}

/// Run `worker(rank, endpoint)` on `nparts` concurrent OS threads over a
/// fresh fully-connected fabric. Returns the outputs in rank order, or the
/// most informative [`RankFailure`] if any rank failed.
pub fn run_ranks<T, F>(nparts: usize, worker: F) -> Result<ParallelRun<T>, RankFailure>
where
    T: Send,
    F: Fn(usize, &mut Endpoint) -> T + Sync,
{
    assert!(nparts > 0, "need at least one rank");
    let endpoints = fabric(nparts);

    let results: Vec<Result<(T, FabricStats), String>> = std::thread::scope(|scope| {
        let worker = &worker;
        let handles: Vec<_> = endpoints
            .into_iter()
            .enumerate()
            .map(|(rank, mut ep)| {
                scope.spawn(move || {
                    let out = catch_unwind(AssertUnwindSafe(|| worker(rank, &mut ep)));
                    match out {
                        Ok(value) => {
                            if ep.drained() {
                                Ok((value, ep.stats()))
                            } else {
                                ep.poison();
                                Err("unconsumed messages left in stash".to_string())
                            }
                        }
                        Err(payload) => {
                            ep.poison();
                            Err(panic_message(&payload))
                        }
                    }
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|p| Err(panic_message(&p))))
            .collect()
    });

    let mut outputs = Vec::with_capacity(nparts);
    let mut sent = Vec::with_capacity(nparts);
    let mut stats = Vec::with_capacity(nparts);
    let mut failure: Option<RankFailure> = None;
    for (rank, result) in results.into_iter().enumerate() {
        match result {
            Ok((value, st)) => {
                outputs.push(value);
                sent.push((st.sent_words, st.sent_msgs));
                stats.push(st);
            }
            Err(message) => {
                // Prefer the root cause over the secondary unwinds of
                // ranks that were merely blocked on (or sending to) the
                // rank that actually failed.
                let candidate = RankFailure { rank, message };
                let better = match &failure {
                    None => true,
                    Some(cur) => {
                        is_secondary(&cur.message) && !is_secondary(&candidate.message)
                    }
                };
                if better {
                    failure = Some(candidate);
                }
            }
        }
    }
    match failure {
        Some(f) => Err(f),
        None => Ok(ParallelRun {
            outputs,
            sent,
            fabric: stats,
        }),
    }
}

/// True for failure messages that are consequences of another rank dying
/// (blocked receivers woken by poisoning, sends to a hung-up peer) rather
/// than root causes. Shared with the serving pool's failure triage.
pub(crate) fn is_secondary(message: &str) -> bool {
    message.contains("fabric poisoned") || message.contains("peer rank hung up")
}

pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic>".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Phase;

    #[test]
    fn all_to_all_sum_and_counters() {
        let n = 6usize;
        let run = run_ranks(n, |rank, ep| {
            let me = rank as u32;
            for to in 0..n as u32 {
                if to != me {
                    ep.send(to, 0, Phase::Forward, me, vec![me as f32]);
                }
            }
            let mut sum = 0.0f32;
            for from in 0..n as u32 {
                if from != me {
                    sum += ep.recv(from, 0, Phase::Forward, from)[0];
                }
            }
            sum
        })
        .expect("run succeeds");
        let all: f32 = (0..n as u32).map(|x| x as f32).sum();
        for (rank, &sum) in run.outputs.iter().enumerate() {
            assert_eq!(sum, all - rank as f32, "rank {rank}");
        }
        for &(words, msgs) in &run.sent {
            assert_eq!(words, (n - 1) as u64);
            assert_eq!(msgs, (n - 1) as u64);
        }
        for st in &run.fabric {
            assert_eq!(st.sent_msgs, (n - 1) as u64);
            assert_eq!(st.recv_msgs, (n - 1) as u64);
            assert_eq!(st.peers.len(), n);
            let peer_sent: u64 = st.peers.iter().map(|p| p.sent_msgs).sum();
            assert_eq!(peer_sent, st.sent_msgs);
        }
    }

    #[test]
    fn rank_panic_becomes_error_without_deadlock() {
        // Rank 0 panics before sending; ranks 1..3 block on receives from
        // it and must unwind via fabric poisoning instead of hanging.
        let err = run_ranks(4, |rank, ep| {
            if rank == 0 {
                panic!("injected failure on rank 0");
            }
            ep.recv(0, 0, Phase::Forward, 0);
        })
        .expect_err("run must fail");
        assert_eq!(err.rank, 0);
        assert!(
            err.message.contains("injected failure"),
            "root cause lost: {}",
            err.message
        );
    }

    #[test]
    fn send_to_dead_rank_does_not_mask_root_cause() {
        // Rank 3 dies; rank 1 later sends to it and panics with the
        // secondary "peer rank hung up" — the reported failure must still
        // be rank 3's own panic.
        let err = run_ranks(4, |rank, ep| match rank {
            3 => panic!("rank 3 exploded"),
            1 => {
                std::thread::sleep(std::time::Duration::from_millis(150));
                ep.send(3, 0, Phase::Forward, 0, vec![1.0]);
            }
            _ => {}
        })
        .expect_err("engine must surface the failure");
        assert_eq!(err.rank, 3, "masked by: {}", err.message);
        assert!(err.message.contains("exploded"), "{}", err.message);
    }

    #[test]
    fn unreceived_channel_message_is_an_error() {
        // Rank 0 sends a message rank 1 never receives at all (it stays in
        // the channel, not the stash) — still flagged as a leak. The
        // barrier guarantees the send lands before rank 1 returns.
        let barrier = std::sync::Barrier::new(2);
        let err = run_ranks(2, |rank, ep| {
            if rank == 0 {
                ep.send(1, 0, Phase::Forward, 0, vec![1.0]);
            }
            barrier.wait();
        })
        .expect_err("channel leak must fail");
        assert_eq!(err.rank, 1);
        assert!(err.message.contains("unconsumed"), "{}", err.message);
    }

    #[test]
    fn undrained_stash_is_an_error() {
        // Rank 0 sends two tags; rank 1 consumes only the second, leaving
        // the first stashed — the engine must flag the leak.
        let err = run_ranks(2, |rank, ep| {
            if rank == 0 {
                ep.send(1, 0, Phase::Forward, 0, vec![1.0]);
                ep.send(1, 1, Phase::Forward, 0, vec![2.0]);
            } else {
                assert_eq!(ep.recv(0, 1, Phase::Forward, 0), vec![2.0]);
            }
        })
        .expect_err("stash leak must fail");
        assert_eq!(err.rank, 1);
        assert!(err.message.contains("unconsumed"), "{}", err.message);
    }

    #[test]
    fn timers_aggregate_across_ranks() {
        let run = run_ranks(3, |rank, _ep| {
            let mut t = PhaseTimer::new();
            t.add_secs("spmv", (rank + 1) as f64);
            t
        })
        .expect("run succeeds");
        let merged = run.merged_timer(|t| t);
        assert!((merged.get_secs("spmv") - 6.0).abs() < 1e-9);
    }

    #[test]
    fn outputs_are_in_rank_order() {
        let run = run_ranks(5, |rank, _ep| rank * 10).expect("run succeeds");
        assert_eq!(run.outputs, vec![0, 10, 20, 30, 40]);
    }
}
