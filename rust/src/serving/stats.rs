//! Serving statistics: throughput counters plus a log-bucketed latency
//! histogram with p50/p95/p99 extraction. Recorded by the pool scheduler,
//! readable at any time via [`ServingStats::snapshot`].

use std::sync::Mutex;
use std::time::Instant;

/// Geometric bucket layout: bucket 0 covers (0, `BUCKET0`], bucket i>0
/// covers (`BUCKET0`·G^(i-1), `BUCKET0`·G^i] — 1 µs up to ~27 minutes.
const BUCKET0: f64 = 1e-6;
const GROWTH: f64 = 1.25;
const NBUCKETS: usize = 96;

/// Log-bucketed latency histogram over seconds. Constant memory, O(1)
/// record, quantiles accurate to one bucket (±25 %) — plenty for p50/p95/
/// p99 serving dashboards without storing per-request samples.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    /// Exact smallest sanitized observation (valid when `total > 0`).
    min: f64,
    /// Exact largest sanitized observation.
    max: f64,
    /// Observations above the last bucket's upper edge (~27 min); they
    /// still land in the last bucket for quantiles, but are counted here
    /// instead of being silently clamped.
    overflow: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self {
            counts: vec![0; NBUCKETS],
            total: 0,
            sum: 0.0,
            min: 0.0,
            max: 0.0,
            overflow: 0,
        }
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket_of(secs: f64) -> usize {
        if secs.is_nan() || secs <= BUCKET0 {
            return 0;
        }
        let i = (secs / BUCKET0).ln() / GROWTH.ln();
        (i.ceil() as usize).min(NBUCKETS - 1)
    }

    /// Record one observation (seconds). NaN and negative inputs are
    /// sanitized to 0.0 before bucketing and min/max tracking.
    pub fn record(&mut self, secs: f64) {
        let s = if secs.is_nan() { 0.0 } else { secs.max(0.0) };
        if self.total == 0 || s < self.min {
            self.min = s;
        }
        if s > self.max {
            self.max = s;
        }
        if s > BUCKET0 * GROWTH.powi(NBUCKETS as i32 - 1) {
            self.overflow += 1;
        }
        self.counts[Self::bucket_of(s)] += 1;
        self.total += 1;
        self.sum += s;
    }

    /// Observations recorded so far.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Exact smallest recorded observation, 0.0 when empty.
    pub fn min(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact largest recorded observation, 0.0 when empty — not capped
    /// at bucket resolution, so a p99 outlier's true value survives.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Observations that fell above the last bucket's upper edge.
    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    /// Mean of the recorded observations (exact, not bucketed).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Nearest-rank quantile, `q` in [0, 1]: the upper bound of the bucket
    /// holding the ⌈q·n⌉-th smallest observation. 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return BUCKET0 * GROWTH.powi(i as i32);
            }
        }
        BUCKET0 * GROWTH.powi(NBUCKETS as i32 - 1)
    }
}

#[derive(Debug, Default)]
struct StatsInner {
    requests: u64,
    failed_requests: u64,
    shed_requests: u64,
    batches: u64,
    pool_rebuilds: u64,
    columns: u64,
    edges: f64,
    busy_secs: f64,
    /// Pre-encoding payload bytes moved between ranks (activation words × 4).
    raw_bytes: u64,
    /// Bytes actually shipped over the fabric after the wire codec ran.
    wire_bytes: u64,
    /// Requests requeued onto a respawned generation after theirs failed.
    requests_retried: u64,
    /// Generation respawns actually completed (rank threads re-spawned).
    generations_respawned: u64,
    /// Generation failures rooted in a stall-watchdog trip.
    watchdog_trips: u64,
    /// Generation failures rooted in a payload checksum mismatch.
    checksum_failures: u64,
    /// Requests fast-failed by an open circuit breaker.
    unavailable_requests: u64,
    /// Circuit-breaker state gauge: 0 closed, 1 half-open, 2 open.
    breaker_state: u8,
    latency: LatencyHistogram,
}

/// Shared, thread-safe serving counters. One instance lives for the whole
/// pool lifetime; the scheduler thread records, any thread may snapshot.
pub struct ServingStats {
    inner: Mutex<StatsInner>,
    started: Instant,
}

impl ServingStats {
    /// Fresh counters; the wall clock starts now.
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(StatsInner::default()),
            started: Instant::now(),
        }
    }

    /// One successfully served fused batch: `requests` tickets answered,
    /// `columns` total SpMM columns, `edges` nnz·columns, `service_secs`
    /// end-to-end dispatch time (send → all ranks reported).
    pub(crate) fn record_batch(
        &self,
        requests: usize,
        columns: usize,
        edges: f64,
        service_secs: f64,
    ) {
        let mut s = self.inner.lock().unwrap();
        s.requests += requests as u64;
        s.batches += 1;
        s.columns += columns as u64;
        s.edges += edges;
        s.busy_secs += service_secs;
    }

    /// Per-request submit→reply latency.
    pub(crate) fn record_latency(&self, secs: f64) {
        self.inner.lock().unwrap().latency.record(secs);
    }

    /// One poisoned fused batch and the generation rebuild it forced:
    /// `failed` tickets resolved to a `RankFailure` (retry budget spent),
    /// `retried` were requeued onto the next generation.
    pub(crate) fn record_dispatch_failure(&self, failed: usize, retried: usize) {
        let mut s = self.inner.lock().unwrap();
        s.failed_requests += failed as u64;
        s.requests_retried += retried as u64;
        s.pool_rebuilds += 1;
    }

    /// One completed generation respawn (rank threads are live again).
    pub(crate) fn record_respawn(&self) {
        self.inner.lock().unwrap().generations_respawned += 1;
    }

    /// One generation failure rooted in a stall-watchdog trip.
    pub(crate) fn record_watchdog_trip(&self) {
        self.inner.lock().unwrap().watchdog_trips += 1;
    }

    /// One generation failure rooted in a payload checksum mismatch.
    pub(crate) fn record_checksum_failure(&self) {
        self.inner.lock().unwrap().checksum_failures += 1;
    }

    /// Requests fast-failed (`ServeError::Unavailable`) by an open
    /// circuit breaker — no dispatch, no rebuild.
    pub(crate) fn record_unavailable(&self, requests: usize) {
        self.inner.lock().unwrap().unavailable_requests += requests as u64;
    }

    /// Publish the circuit breaker's state gauge (0 closed, 1 half-open,
    /// 2 open).
    pub(crate) fn set_breaker_state(&self, code: u8) {
        self.inner.lock().unwrap().breaker_state = code;
    }

    /// Requests shed for blowing their queue-wait SLO (deadline load
    /// shedding) — failed without a dispatch, so no rebuild.
    pub(crate) fn record_shed(&self, requests: usize) {
        self.inner.lock().unwrap().shed_requests += requests as u64;
    }

    /// Payload bytes one fused batch moved between ranks: raw
    /// (pre-encoding) vs. actually on the wire — their ratio is the live
    /// codec compression factor.
    pub(crate) fn record_wire(&self, raw_bytes: u64, wire_bytes: u64) {
        let mut s = self.inner.lock().unwrap();
        s.raw_bytes += raw_bytes;
        s.wire_bytes += wire_bytes;
    }

    /// Consistent point-in-time copy of every counter plus the derived
    /// rates (edges/s against wall and busy time).
    pub fn snapshot(&self) -> StatsSnapshot {
        let s = self.inner.lock().unwrap();
        let wall = self.started.elapsed().as_secs_f64();
        StatsSnapshot {
            requests: s.requests,
            failed_requests: s.failed_requests,
            shed_requests: s.shed_requests,
            batches: s.batches,
            pool_rebuilds: s.pool_rebuilds,
            columns: s.columns,
            mean_batch: if s.batches == 0 {
                0.0
            } else {
                s.columns as f64 / s.batches as f64
            },
            edges_per_sec: if wall > 0.0 { s.edges / wall } else { 0.0 },
            edges_per_sec_busy: if s.busy_secs > 0.0 {
                s.edges / s.busy_secs
            } else {
                0.0
            },
            raw_bytes: s.raw_bytes,
            wire_bytes: s.wire_bytes,
            requests_retried: s.requests_retried,
            generations_respawned: s.generations_respawned,
            watchdog_trips: s.watchdog_trips,
            checksum_failures: s.checksum_failures,
            unavailable_requests: s.unavailable_requests,
            breaker_state: s.breaker_state,
            p50_secs: s.latency.quantile(0.50),
            p95_secs: s.latency.quantile(0.95),
            p99_secs: s.latency.quantile(0.99),
            mean_latency_secs: s.latency.mean(),
            min_latency_secs: s.latency.min(),
            max_latency_secs: s.latency.max(),
            overflow_latencies: s.latency.overflow(),
            wall_secs: wall,
        }
    }
}

impl Default for ServingStats {
    fn default() -> Self {
        Self::new()
    }
}

/// Point-in-time view of the serving counters.
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    pub requests: u64,
    pub failed_requests: u64,
    /// Requests failed for blowing their queue-wait SLO (load shedding).
    pub shed_requests: u64,
    /// Fused dispatches; `requests / batches` ≥ 1 shows coalescing.
    pub batches: u64,
    /// Generation rebuilds forced by rank failures.
    pub pool_rebuilds: u64,
    /// Total SpMM columns served.
    pub columns: u64,
    pub mean_batch: f64,
    /// Aggregate edges/s over wall-clock since pool start.
    pub edges_per_sec: f64,
    /// Edges/s over time the ranks were actually serving a batch.
    pub edges_per_sec_busy: f64,
    /// Pre-encoding payload bytes moved between ranks over the pool's
    /// lifetime (what an uncompressed fabric would have shipped).
    pub raw_bytes: u64,
    /// Bytes actually shipped after the wire codec — equal to `raw_bytes`
    /// under `Codec::F32`.
    pub wire_bytes: u64,
    /// Requests requeued onto a respawned generation after theirs was
    /// poisoned (each requeue of each ticket counts once).
    pub requests_retried: u64,
    /// Generation respawns completed after failures.
    pub generations_respawned: u64,
    /// Generation failures rooted in a stall-watchdog trip.
    pub watchdog_trips: u64,
    /// Generation failures rooted in a payload checksum mismatch.
    pub checksum_failures: u64,
    /// Requests fast-failed (`Unavailable`) by an open circuit breaker.
    pub unavailable_requests: u64,
    /// Circuit-breaker state gauge: 0 closed, 1 half-open, 2 open.
    pub breaker_state: u8,
    pub p50_secs: f64,
    pub p95_secs: f64,
    pub p99_secs: f64,
    pub mean_latency_secs: f64,
    /// Exact smallest request latency observed (not bucket-rounded).
    pub min_latency_secs: f64,
    /// Exact largest request latency observed (not bucket-rounded).
    pub max_latency_secs: f64,
    /// Latency samples above the histogram's last bucket (~27 min).
    pub overflow_latencies: u64,
    pub wall_secs: f64,
}

impl StatsSnapshot {
    /// Live compression factor: raw payload bytes per byte actually on
    /// the wire. 1.0 under `Codec::F32` (and when nothing moved yet).
    pub fn wire_compression(&self) -> f64 {
        if self.wire_bytes == 0 {
            1.0
        } else {
            self.raw_bytes as f64 / self.wire_bytes as f64
        }
    }

    /// Human label for the breaker gauge.
    pub fn breaker_label(&self) -> &'static str {
        match self.breaker_state {
            0 => "closed",
            1 => "half-open",
            _ => "open",
        }
    }

    /// Human summary for example/bench output.
    pub fn render(&self) -> String {
        format!(
            "{} requests in {} batches (mean {:.1} cols/batch), {:.2e} edges/s wall \
             ({:.2e} busy), latency p50 {:.2} ms p95 {:.2} ms p99 {:.2} ms \
             (mean {:.2} ms, min {:.2} ms, max {:.2} ms), \
             wire {} B of {} B raw ({:.2}x), \
             {} failed, {} shed, {} rebuilds \
             ({} retried, {} respawned, {} watchdog trips, {} checksum failures, \
             {} unavailable, breaker {})",
            self.requests,
            self.batches,
            self.mean_batch,
            self.edges_per_sec,
            self.edges_per_sec_busy,
            self.p50_secs * 1e3,
            self.p95_secs * 1e3,
            self.p99_secs * 1e3,
            self.mean_latency_secs * 1e3,
            self.min_latency_secs * 1e3,
            self.max_latency_secs * 1e3,
            self.wire_bytes,
            self.raw_bytes,
            self.wire_compression(),
            self.failed_requests,
            self.shed_requests,
            self.pool_rebuilds,
            self.requests_retried,
            self.generations_respawned,
            self.watchdog_trips,
            self.checksum_failures,
            self.unavailable_requests,
            self.breaker_label(),
        )
    }

    /// Machine-readable JSON (the CI smoke job writes `BENCH_serving.json`
    /// from this).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"requests\":{},\"failed_requests\":{},\"shed_requests\":{},\
             \"batches\":{},\"pool_rebuilds\":{},\
             \"requests_retried\":{},\"generations_respawned\":{},\
             \"watchdog_trips\":{},\"checksum_failures\":{},\
             \"unavailable_requests\":{},\"breaker_state\":{},\
             \"columns\":{},\"mean_batch\":{:.3},\"edges_per_sec\":{:.1},\
             \"edges_per_sec_busy\":{:.1},\
             \"raw_bytes\":{},\"wire_bytes\":{},\"wire_compression\":{:.4},\
             \"p50_ms\":{:.4},\"p95_ms\":{:.4},\
             \"p99_ms\":{:.4},\"mean_latency_ms\":{:.4},\
             \"min_ms\":{:.4},\"max_ms\":{:.4},\"overflow_latencies\":{},\
             \"wall_secs\":{:.4}}}",
            self.requests,
            self.failed_requests,
            self.shed_requests,
            self.batches,
            self.pool_rebuilds,
            self.requests_retried,
            self.generations_respawned,
            self.watchdog_trips,
            self.checksum_failures,
            self.unavailable_requests,
            self.breaker_state,
            self.columns,
            self.mean_batch,
            self.edges_per_sec,
            self.edges_per_sec_busy,
            self.raw_bytes,
            self.wire_bytes,
            self.wire_compression(),
            self.p50_secs * 1e3,
            self.p95_secs * 1e3,
            self.p99_secs * 1e3,
            self.mean_latency_secs * 1e3,
            self.min_latency_secs * 1e3,
            self.max_latency_secs * 1e3,
            self.overflow_latencies,
            self.wall_secs,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_bracket_recorded_values() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100u32 {
            h.record(i as f64 * 1e-3); // 1..100 ms
        }
        assert_eq!(h.count(), 100);
        // bucketed quantiles are exact to one geometric bucket (±25 %)
        let p50 = h.quantile(0.50);
        assert!(p50 > 0.035 && p50 < 0.070, "p50 {p50}");
        let p99 = h.quantile(0.99);
        assert!(p99 > 0.079 && p99 < 0.130, "p99 {p99}");
        assert!((h.mean() - 0.0505).abs() < 1e-6);
    }

    #[test]
    fn quantile_monotone_and_empty_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
        let mut h = LatencyHistogram::new();
        h.record(1e-4);
        h.record(1e-2);
        h.record(1.0);
        assert!(h.quantile(0.1) <= h.quantile(0.5));
        assert!(h.quantile(0.5) <= h.quantile(0.99));
    }

    #[test]
    fn extreme_values_clamp_to_edge_buckets() {
        let mut h = LatencyHistogram::new();
        h.record(0.0);
        h.record(-1.0);
        h.record(1e9);
        assert_eq!(h.count(), 3);
        assert!(h.quantile(1.0) > 0.0);
        // negatives sanitize to 0.0; the exact extremes survive bucketing
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 1e9);
        assert_eq!(h.overflow(), 1);
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.0), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.quantile(1.0), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.overflow(), 0);
    }

    #[test]
    fn single_sample_pins_every_statistic() {
        let mut h = LatencyHistogram::new();
        h.record(0.0042);
        assert_eq!(h.count(), 1);
        assert_eq!(h.min(), 0.0042);
        assert_eq!(h.max(), 0.0042);
        assert_eq!(h.overflow(), 0);
        assert!((h.mean() - 0.0042).abs() < 1e-12);
        // every quantile reads the one occupied bucket, whose upper edge
        // brackets the sample within one geometric step
        for q in [0.0, 0.5, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!(v >= 0.0042 / 1.25 && v <= 0.0042 * 1.25, "q{q} -> {v}");
        }
    }

    #[test]
    fn bucket_boundary_values_stay_in_their_bucket() {
        // exactly BUCKET0 lands in bucket 0
        let mut h = LatencyHistogram::new();
        h.record(1e-6);
        assert!((h.quantile(1.0) - 1e-6).abs() < 1e-15);
        // a value on the next bucket edge reads back within one growth
        // factor of itself (never below its own bucket's lower edge)
        let v = 1e-6 * 1.25;
        let mut h = LatencyHistogram::new();
        h.record(v);
        let q = h.quantile(1.0);
        assert!(q >= v / 1.25 - 1e-15 && q <= v * 1.25 + 1e-15, "edge -> {q}");
    }

    #[test]
    fn overflow_counted_not_clamped_silently() {
        let mut h = LatencyHistogram::new();
        h.record(1.0);
        h.record(5e3); // above the ~27 min last-bucket edge
        assert_eq!(h.count(), 2);
        assert_eq!(h.overflow(), 1);
        assert_eq!(h.max(), 5e3);
        // the overflow sample still participates in quantiles (last bucket)
        assert!(h.quantile(1.0) >= 1e3);
    }

    #[test]
    fn stats_snapshot_aggregates() {
        let stats = ServingStats::new();
        stats.record_batch(3, 12, 1200.0, 0.010);
        stats.record_batch(1, 4, 400.0, 0.010);
        stats.record_latency(0.002);
        stats.record_latency(0.004);
        stats.record_latency(0.006);
        stats.record_latency(0.008);
        stats.record_dispatch_failure(2, 3);
        stats.record_respawn();
        stats.record_watchdog_trip();
        stats.record_checksum_failure();
        stats.record_unavailable(4);
        stats.set_breaker_state(2);
        stats.record_shed(3);
        stats.record_wire(4000, 1000);
        stats.record_wire(4000, 3000);
        let s = stats.snapshot();
        assert_eq!(s.raw_bytes, 8000);
        assert_eq!(s.wire_bytes, 4000);
        assert!((s.wire_compression() - 2.0).abs() < 1e-9);
        assert!(s.to_json().contains("\"wire_compression\":2.0000"));
        assert!(s.render().contains("(2.00x)"));
        assert_eq!(s.requests, 4);
        assert_eq!(s.failed_requests, 2);
        assert_eq!(s.shed_requests, 3);
        assert!(s.to_json().contains("\"shed_requests\":3"));
        assert!(s.render().contains("3 shed"));
        assert_eq!(s.batches, 2);
        assert_eq!(s.pool_rebuilds, 1);
        assert_eq!(s.requests_retried, 3);
        assert_eq!(s.generations_respawned, 1);
        assert_eq!(s.watchdog_trips, 1);
        assert_eq!(s.checksum_failures, 1);
        assert_eq!(s.unavailable_requests, 4);
        assert_eq!(s.breaker_state, 2);
        assert_eq!(s.breaker_label(), "open");
        assert_eq!(s.columns, 16);
        assert!((s.mean_batch - 8.0).abs() < 1e-9);
        assert!((s.edges_per_sec_busy - 1600.0 / 0.020).abs() < 1e-6);
        assert!(s.p50_secs > 0.0 && s.p99_secs >= s.p50_secs);
        let json = s.to_json();
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"requests\":4"));
        assert!(json.contains("\"p99_ms\":"));
        assert!(json.contains("\"requests_retried\":3"));
        assert!(json.contains("\"generations_respawned\":1"));
        assert!(json.contains("\"watchdog_trips\":1"));
        assert!(json.contains("\"checksum_failures\":1"));
        assert!(json.contains("\"unavailable_requests\":4"));
        assert!(json.contains("\"breaker_state\":2"));
        assert!(s.render().contains("breaker open"));
    }
}
