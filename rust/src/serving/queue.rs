//! Request-queue front-end of the serving pool: submitters push [`Pending`]
//! entries into a mutex+condvar queue and hold a [`Ticket`] to block on or
//! poll; the scheduler thread pops and coalesces them into fused batches,
//! shedding tickets whose queue wait has already blown their deadline.

use crate::runtime::RankFailure;
use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a submitted request did not produce an output.
#[derive(Debug, Clone)]
pub enum ServeError {
    /// A rank failed while serving the fused batch this request landed in;
    /// the pool rebuilt its generation and keeps serving.
    Rank(RankFailure),
    /// Load shedding: the request waited in the queue longer than the SLO
    /// it was submitted with, so the scheduler failed it instead of
    /// serving it late ([`crate::serving::RankPool::submit_with_deadline`]).
    DeadlineExceeded {
        /// How long the request had been queued when the scheduler reached
        /// it.
        waited: Duration,
        /// The queue-wait SLO it was submitted with.
        slo: Duration,
    },
    /// The pool shut down before the request completed.
    Shutdown,
    /// Fast-fail: the pool's circuit breaker is open after repeated
    /// consecutive generation failures, so the scheduler rejects requests
    /// immediately instead of queueing them behind a crash loop. The
    /// breaker re-probes with a half-open trial generation after its
    /// cooldown.
    Unavailable {
        /// Consecutive generation failures that tripped the breaker.
        failures: u32,
    },
}

impl ServeError {
    /// The underlying rank failure, when that is what killed the request.
    pub fn rank_failure(&self) -> Option<&RankFailure> {
        match self {
            ServeError::Rank(f) => Some(f),
            _ => None,
        }
    }

    /// True for deadline-shed requests.
    pub fn is_deadline(&self) -> bool {
        matches!(self, ServeError::DeadlineExceeded { .. })
    }

    /// True for requests fast-failed by an open circuit breaker.
    pub fn is_unavailable(&self) -> bool {
        matches!(self, ServeError::Unavailable { .. })
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Rank(rf) => write!(f, "{rf}"),
            ServeError::DeadlineExceeded { waited, slo } => write!(
                f,
                "deadline exceeded: queued {:.3} ms against an SLO of {:.3} ms",
                waited.as_secs_f64() * 1e3,
                slo.as_secs_f64() * 1e3
            ),
            ServeError::Shutdown => {
                write!(f, "pool shut down before the request completed")
            }
            ServeError::Unavailable { failures } => write!(
                f,
                "pool unavailable: circuit breaker open after {failures} consecutive \
                 generation failures"
            ),
        }
    }
}

impl From<RankFailure> for ServeError {
    fn from(f: RankFailure) -> Self {
        ServeError::Rank(f)
    }
}

/// What a ticket resolves to: the `[nL × b]` row-major output, or why the
/// request was not served.
pub(crate) type Reply = Result<Vec<f32>, ServeError>;

/// One queued inference request.
pub(crate) struct Pending {
    /// `[n0 × b]` row-major inputs.
    pub x0: Vec<f32>,
    pub b: usize,
    /// Reply channel of the submitter's ticket.
    pub tx: Sender<Reply>,
    pub submitted: Instant,
    /// Queue-wait SLO: the scheduler sheds this request instead of serving
    /// it once `submitted.elapsed()` exceeds it. `None` = serve whenever.
    pub deadline: Option<Duration>,
    /// Remaining requeue attempts if a generation fails under this
    /// request: innocent members of a poisoned fused batch go back to the
    /// front of the queue until this budget runs out, after which the
    /// ticket resolves to the typed [`ServeError::Rank`] error.
    pub retries_left: u32,
}

/// Handle to one submitted request. Block with [`Ticket::wait`] or poll
/// with [`Ticket::poll`]; dropping it abandons the result harmlessly.
pub struct Ticket {
    pub(crate) rx: Receiver<Reply>,
}

impl Ticket {
    /// Block until the request completes (or is failed/shed).
    pub fn wait(self) -> Result<Vec<f32>, ServeError> {
        self.rx.recv().unwrap_or(Err(ServeError::Shutdown))
    }

    /// Non-blocking: `None` while the request is still in flight.
    pub fn poll(&self) -> Option<Result<Vec<f32>, ServeError>> {
        match self.rx.try_recv() {
            Ok(reply) => Some(reply),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => Some(Err(ServeError::Shutdown)),
        }
    }
}

/// Multiple of `max_wait` at which an idle gap stops carrying information:
/// a gap this long already proves the batch window cannot fill, and
/// anything beyond it is the service being *idle*, not traffic being
/// sparse. See [`QueueState::note_arrival`].
pub(crate) const GAP_CLAMP_MULT: u32 = 8;

/// Scheduler-visible queue state, guarded by [`SharedQueue::state`].
#[derive(Default)]
pub(crate) struct QueueState {
    pub queue: VecDeque<Pending>,
    pub shutdown: bool,
    /// EWMA of the request inter-arrival gap in seconds — the adaptive
    /// batching signal. `None` until two arrivals have been observed.
    pub ewma_gap: Option<f64>,
    /// Clamp applied to each gap sample before it enters the EWMA
    /// (`None` = unclamped). The pool sets this to
    /// `GAP_CLAMP_MULT × max_wait`: without it, one long idle period (a
    /// quiet night) drives the EWMA so high that the scheduler keeps
    /// skipping the coalesce wait long after dense traffic returns.
    pub gap_clamp: Option<Duration>,
    last_arrival: Option<Instant>,
}

impl QueueState {
    /// Fold one arrival into the inter-arrival EWMA (α = 0.2), clamping
    /// the gap sample first so idle periods saturate instead of poisoning
    /// the average. The clamp sits above the `effective_wait` threshold
    /// (`max_wait`), so genuinely sparse traffic still disables the wait
    /// window — but a handful of dense arrivals now brings the EWMA back
    /// under the threshold.
    pub fn note_arrival(&mut self, now: Instant) {
        if let Some(prev) = self.last_arrival {
            let mut gap = now.duration_since(prev).as_secs_f64();
            if let Some(clamp) = self.gap_clamp {
                gap = gap.min(clamp.as_secs_f64());
            }
            self.ewma_gap = Some(match self.ewma_gap {
                Some(e) => 0.8 * e + 0.2 * gap,
                None => gap,
            });
        }
        self.last_arrival = Some(now);
    }
}

/// The queue shared between submitters and the scheduler thread.
#[derive(Default)]
pub(crate) struct SharedQueue {
    pub state: Mutex<QueueState>,
    pub cv: Condvar,
}

/// How long the scheduler holds an under-filled batch open waiting for
/// more arrivals. Adaptive policy: once the observed inter-arrival gap
/// exceeds `max_wait`, waiting cannot fill the batch — traffic is too
/// sparse — so dispatch immediately instead of taxing every request with
/// queueing latency for nothing.
pub(crate) fn effective_wait(max_wait: Duration, ewma_gap: Option<f64>) -> Duration {
    match ewma_gap {
        Some(gap) if gap > max_wait.as_secs_f64() => Duration::ZERO,
        _ => max_wait,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_wait_dense_traffic_keeps_window() {
        let w = Duration::from_millis(2);
        assert_eq!(effective_wait(w, None), w);
        assert_eq!(effective_wait(w, Some(0.0005)), w);
    }

    #[test]
    fn effective_wait_sparse_traffic_skips_window() {
        let w = Duration::from_millis(2);
        assert_eq!(effective_wait(w, Some(0.5)), Duration::ZERO);
    }

    #[test]
    fn ewma_tracks_arrival_gaps() {
        let mut st = QueueState::default();
        let t0 = Instant::now();
        st.note_arrival(t0);
        assert!(st.ewma_gap.is_none(), "one arrival gives no gap yet");
        st.note_arrival(t0 + Duration::from_millis(10));
        let g1 = st.ewma_gap.expect("gap after two arrivals");
        assert!((g1 - 0.010).abs() < 1e-9);
        st.note_arrival(t0 + Duration::from_millis(30));
        let g2 = st.ewma_gap.unwrap();
        assert!((g2 - (0.8 * 0.010 + 0.2 * 0.020)).abs() < 1e-9);
    }

    #[test]
    fn idle_gap_is_clamped_before_entering_the_ewma() {
        // regression: one long idle period must not convince the scheduler
        // that traffic is sparse for ages after load returns
        let max_wait = Duration::from_millis(2);
        let mut st = QueueState {
            gap_clamp: Some(max_wait * GAP_CLAMP_MULT),
            ..QueueState::default()
        };
        let t0 = Instant::now();
        st.note_arrival(t0);
        st.note_arrival(t0 + Duration::from_millis(1)); // dense traffic
        // a one-hour quiet period
        st.note_arrival(t0 + Duration::from_secs(3600));
        let after_idle = st.ewma_gap.unwrap();
        let clamp = (max_wait * GAP_CLAMP_MULT).as_secs_f64();
        assert!(
            after_idle <= 0.8 * 0.001 + 0.2 * clamp + 1e-9,
            "idle gap leaked into the EWMA: {after_idle}"
        );
        // the clamp saturates ABOVE max_wait: sparse traffic still skips
        // the window right after the idle period
        assert_eq!(effective_wait(max_wait, st.ewma_gap), Duration::ZERO);
        // ... and a handful of dense arrivals restores the window
        let mut t = t0 + Duration::from_secs(3600);
        for _ in 0..12 {
            t += Duration::from_micros(100);
            st.note_arrival(t);
        }
        assert_eq!(
            effective_wait(max_wait, st.ewma_gap),
            max_wait,
            "dense traffic must re-enable the coalesce wait quickly (ewma {:?})",
            st.ewma_gap
        );
        // unclamped state keeps the old behaviour
        let mut raw = QueueState::default();
        raw.note_arrival(t0);
        raw.note_arrival(t0 + Duration::from_secs(3600));
        assert!(raw.ewma_gap.unwrap() > 3599.0);
    }

    #[test]
    fn ticket_poll_none_then_value() {
        let (tx, rx) = std::sync::mpsc::channel();
        let ticket = Ticket { rx };
        assert!(ticket.poll().is_none());
        tx.send(Ok(vec![1.0])).unwrap();
        match ticket.poll() {
            Some(Ok(v)) => assert_eq!(v, vec![1.0]),
            other => panic!("unexpected poll result: {other:?}"),
        }
    }

    #[test]
    fn dropped_sender_resolves_to_shutdown() {
        let (tx, rx) = std::sync::mpsc::channel::<Reply>();
        drop(tx);
        let ticket = Ticket { rx };
        let err = ticket.wait().expect_err("must fail");
        assert!(matches!(err, ServeError::Shutdown));
        assert!(err.to_string().contains("shut down"), "{err}");
        assert!(err.rank_failure().is_none() && !err.is_deadline());
    }

    #[test]
    fn serve_error_accessors_and_display() {
        let e = ServeError::Rank(RankFailure {
            rank: 3,
            message: "boom".into(),
        });
        assert_eq!(e.rank_failure().unwrap().rank, 3);
        assert!(e.to_string().contains("boom"));
        let d = ServeError::DeadlineExceeded {
            waited: Duration::from_millis(5),
            slo: Duration::from_millis(2),
        };
        assert!(d.is_deadline());
        assert!(d.to_string().contains("deadline exceeded"), "{d}");
        let u = ServeError::Unavailable { failures: 5 };
        assert!(u.is_unavailable());
        assert!(!e.is_unavailable() && !d.is_unavailable());
        assert!(u.rank_failure().is_none() && !u.is_deadline());
        assert!(u.to_string().contains("circuit breaker open after 5"), "{u}");
    }
}
