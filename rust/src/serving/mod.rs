//! Persistent serving subsystem — the "serve heavy traffic" layer on top
//! of the row-wise partitioned SpMM engine.
//!
//! The one-shot engine ([`crate::runtime::parallel`]) rebuilds rank states
//! and respawns one OS thread per rank on every call; at-scale sparse-DNN
//! serving gets its throughput by amortizing that setup across a stream of
//! requests. This module provides:
//!
//! - [`RankPool`] — spawns the rank threads **once** per pool generation;
//!   each thread builds its [`crate::coordinator::RankState`] and scratch
//!   buffers once and then serves fused batches dispatched over control
//!   channels, preserving the engine's panic→[`crate::runtime::RankFailure`]
//!   poisoning semantics (a failed generation is torn down and respawned,
//!   so one bad request never takes the pool down);
//! - a request-queue front-end — [`RankPool::submit`] returns a [`Ticket`]
//!   the caller blocks on ([`Ticket::wait`]) or polls ([`Ticket::poll`]);
//!   [`RankPool::submit_with_deadline`] attaches a queue-wait SLO, and the
//!   scheduler **sheds** tickets that blew it
//!   ([`ServeError::DeadlineExceeded`]) instead of serving them late;
//! - an adaptive micro-batching scheduler — queued requests are coalesced
//!   into one fused SpMM batch up to [`PoolConfig::max_batch`] columns or
//!   [`PoolConfig::max_wait`], and the wait window is skipped entirely
//!   while the observed inter-arrival gap says it cannot fill a batch;
//! - a failure-recovery pipeline ([`RecoveryConfig`]) — innocent requests
//!   from a poisoned fused batch are requeued with a bounded per-ticket
//!   retry budget, generations are respawned under seeded exponential
//!   [`Backoff`] with equal jitter, and a [`Breaker`] fast-fails requests
//!   ([`ServeError::Unavailable`]) while the pool is in a crash loop,
//!   half-opening a trial after its cooldown;
//! - [`ServingStats`] — throughput counters plus a latency histogram with
//!   p50/p95/p99 ([`StatsSnapshot`]), including the recovery counters
//!   (retries, respawns, watchdog trips, checksum failures, breaker state).
//!
//! See `examples/inference_serving.rs` for the end-to-end request loop,
//! `benches/table2_throughput.rs` for pool-vs-one-shot throughput, and
//! `docs/ROBUSTNESS.md` for the chaos/fault-injection contract.

mod pool;
mod queue;
mod recovery;
mod stats;

pub use pool::{PoolConfig, PoolSummary, RankPool};
pub use queue::{ServeError, Ticket};
pub use recovery::{Backoff, Breaker, BreakerState, RecoveryConfig};
pub use stats::{LatencyHistogram, ServingStats, StatsSnapshot};
