//! The long-lived rank pool: rank threads and their [`RankState`]s are
//! built **once** per pool generation and serve a stream of fused batches
//! dispatched over control channels — amortizing partition, plan, state
//! build, and thread spawn across every request, where the one-shot
//! engine ([`crate::runtime::parallel`]) pays them per call.
//!
//! Failure semantics extend the one-shot engine's: a rank panic (or an
//! injected fault, stall-watchdog trip, or payload checksum mismatch —
//! see [`crate::runtime::fault`]) poisons the fabric so blocked peers
//! unwind instead of deadlocking, and the poisoned generation is torn
//! down. Recovery then kicks in ([`RecoveryConfig`]): innocent requests
//! from the poisoned fused batch are **requeued** onto the respawned
//! generation until their per-ticket retry budget runs out, respawns are
//! spaced by seeded exponential [`Backoff`] with jitter, and after
//! `breaker_threshold` consecutive generation failures a circuit
//! [`Breaker`] fast-fails requests ([`ServeError::Unavailable`]) until a
//! half-open trial succeeds — the pool stays serviceable without queueing
//! traffic behind a crash loop.

use crate::comm::{fabric_with, Codec, Endpoint};
use crate::coordinator::sgd::assemble_outputs;
use crate::coordinator::{ExecMode, RankScratch, RankState};
use crate::dnn::SparseNet;
use crate::obs::{MetricsRegistry, Span, TraceMode, Tracer, NO_CHUNK, NO_LAYER};
use crate::partition::ServingPlan;
use crate::runtime::fault::{self, FaultPlan};
use crate::runtime::parallel::{is_secondary, panic_message};
use crate::runtime::RankFailure;
use crate::serving::queue::{
    effective_wait, Pending, ServeError, SharedQueue, Ticket, GAP_CLAMP_MULT,
};
use crate::serving::recovery::{Backoff, Breaker, BreakerState, RecoveryConfig};
use crate::serving::stats::{ServingStats, StatsSnapshot};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Micro-batching and sizing knobs for a [`RankPool`].
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Rank threads (row-block partitions) kept alive by the pool.
    pub nranks: usize,
    /// Maximum columns coalesced into one fused SpMM dispatch. A single
    /// request larger than this is served alone, never split.
    pub max_batch: usize,
    /// Longest an under-filled batch is held open waiting for arrivals,
    /// measured from the oldest queued request's submit time.
    pub max_wait: Duration,
    /// Adaptive batching: skip the wait window entirely while the observed
    /// inter-arrival gap exceeds `max_wait` (sparse traffic cannot fill a
    /// batch, so holding one open only adds latency).
    pub adaptive: bool,
    /// Which per-rank engine the pool threads run: the send-side pipelined
    /// schedule (default, now that its bar has CI history), the overlapped
    /// split-CSR path, or the blocking baseline.
    pub mode: ExecMode,
    /// Wire codec for the fabric payloads between pool ranks (forward
    /// activations only — serving never runs a backward phase).
    /// [`Codec::F32`] is bit-exact; [`Codec::F16`]/[`Codec::Int8`] trade
    /// bounded activation error for 2–4× fewer bytes on the wire (the
    /// stats report the live compression ratio).
    pub codec: Codec,
    /// Explicit fault-injection plan for the pool's fabrics. `None`
    /// (default) falls back to the process-wide `SPDNN_FAULT` plan
    /// ([`crate::runtime::fault::from_env`]); the chaos tests pass one
    /// directly so runs stay deterministic regardless of the environment.
    pub faults: Option<Arc<FaultPlan>>,
    /// Stall-watchdog deadline for every fabric `recv` in the pool's rank
    /// threads: a receive that blocks longer than this poisons the fabric
    /// with a typed stall failure instead of hanging the generation.
    /// `None` defers to the fault plan's `watchdog_ms` (no watchdog when
    /// that is zero too).
    pub watchdog: Option<Duration>,
    /// Failure-recovery knobs: per-ticket retry budget, respawn backoff
    /// schedule, circuit-breaker threshold and cooldown.
    pub recovery: RecoveryConfig,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self {
            nranks: 4,
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            adaptive: true,
            mode: ExecMode::pipelined(),
            codec: Codec::F32,
            faults: None,
            watchdog: None,
            recovery: RecoveryConfig::default(),
        }
    }
}

/// One fused batch broadcast to every rank of the current generation.
struct Job {
    /// `[n0 × b]` row-major fused inputs.
    x0: Vec<f32>,
    b: usize,
}

enum RankCmd {
    Run(Arc<Job>),
    Shutdown,
}

/// Owned output rows of one rank for one job: (global row, `[b]` values).
type RankRows = Vec<(u32, Vec<f32>)>;

/// One rank's successful job result: owned output rows plus the raw/wire
/// payload bytes this job moved through the rank's endpoint (the deltas
/// of [`Endpoint::sent_raw_bytes`] / [`Endpoint::sent_wire_bytes`]).
struct RankOut {
    rows: RankRows,
    raw_bytes: u64,
    wire_bytes: u64,
}

/// Reply of one rank for one job (or the panic/leak message that killed
/// it).
type RankReply = (usize, Result<RankOut, String>);

/// One set of live rank threads over one fabric. Discarded and respawned
/// whenever a request poisons the fabric.
///
/// Jobs are strictly serialized: the scheduler collects every rank's reply
/// (and each rank passes its drained-stash check) before the next job is
/// dispatched, so reusing the per-layer fabric tags across jobs can never
/// mismatch messages from different requests.
struct Generation {
    cmd_tx: Vec<Sender<RankCmd>>,
    res_rx: Receiver<RankReply>,
    /// Extra endpoint never used for traffic: lets the scheduler poison
    /// the fabric during teardown so nothing can stay blocked in `recv`.
    observer: Endpoint,
    handles: Vec<JoinHandle<()>>,
}

fn spawn_generation(
    net: &Arc<SparseNet>,
    sp: &Arc<ServingPlan>,
    mode: ExecMode,
    plan: &Option<Arc<FaultPlan>>,
    watchdog: Option<Duration>,
) -> Generation {
    let nranks = sp.nranks();
    let mut endpoints = fabric_with(nranks + 1, plan.clone(), watchdog);
    let observer = endpoints.pop().expect("fabric is non-empty");
    let (res_tx, res_rx) = channel();
    let mut cmd_tx = Vec::with_capacity(nranks);
    let mut handles = Vec::with_capacity(nranks);
    for (rank, ep) in endpoints.into_iter().enumerate() {
        let (tx, rx) = channel::<RankCmd>();
        let net = Arc::clone(net);
        let sp = Arc::clone(sp);
        let res = res_tx.clone();
        let handle = std::thread::Builder::new()
            .name(format!("spdnn-pool-rank-{rank}"))
            .spawn(move || rank_loop(rank, ep, &net, &sp, mode, &rx, &res))
            .expect("failed to spawn pool rank thread");
        cmd_tx.push(tx);
        handles.push(handle);
    }
    Generation {
        cmd_tx,
        res_rx,
        observer,
        handles,
    }
}

/// Long-lived body of one pool rank thread: build the rank state once,
/// then serve jobs until shutdown or failure. Runs the same
/// [`RankState::infer_owned_outputs`] body as the one-shot engine, with
/// the engine's lifecycle invariants (panic → poison + error report,
/// drained-stash check after every job) enforced per job instead of per
/// process.
fn rank_loop(
    rank: usize,
    mut ep: Endpoint,
    net: &SparseNet,
    sp: &ServingPlan,
    mode: ExecMode,
    cmds: &Receiver<RankCmd>,
    res: &Sender<RankReply>,
) {
    let mut state = RankState::build(net, &sp.part, &sp.plan, rank as u32, mode);
    let mut scratch = RankScratch::new();
    let (mut prev_raw, mut prev_wire) = (0u64, 0u64);
    loop {
        let job = match cmds.recv() {
            Ok(RankCmd::Run(job)) => job,
            Ok(RankCmd::Shutdown) | Err(_) => {
                // Final drain check: a clean generation leaves no messages.
                let reply = if ep.drained() {
                    Ok(RankOut {
                        rows: Vec::new(),
                        raw_bytes: 0,
                        wire_bytes: 0,
                    })
                } else {
                    Err("unconsumed messages left in stash at shutdown".to_string())
                };
                let _ = res.send((rank, reply));
                return;
            }
        };
        let out = catch_unwind(AssertUnwindSafe(|| {
            // chaos failpoint: an armed fault plan may panic or stall here,
            // exactly where a real compute fault would surface
            ep.compute_failpoint();
            state.infer_owned_outputs(&mut ep, &sp.plan, &job.x0, job.b, &mut scratch)
        }));
        match out {
            Ok(rows) => {
                if ep.drained() {
                    let out = RankOut {
                        rows,
                        raw_bytes: ep.sent_raw_bytes - prev_raw,
                        wire_bytes: ep.sent_wire_bytes - prev_wire,
                    };
                    (prev_raw, prev_wire) = (ep.sent_raw_bytes, ep.sent_wire_bytes);
                    if res.send((rank, Ok(out))).is_err() {
                        return; // pool dropped mid-flight
                    }
                } else {
                    ep.poison();
                    let msg = "unconsumed messages left in stash".to_string();
                    let _ = res.send((rank, Err(msg)));
                    return;
                }
            }
            Err(payload) => {
                ep.poison();
                let _ = res.send((rank, Err(panic_message(&payload))));
                return;
            }
        }
    }
}

/// Tear down a (possibly poisoned) generation: wake anything still blocked
/// on the fabric, close the control channels, join every rank thread.
fn teardown(gen: Generation) {
    gen.observer.poison();
    drop(gen.cmd_tx);
    drop(gen.res_rx);
    for h in gen.handles {
        let _ = h.join();
    }
}

struct SchedulerReport {
    leaked_ranks: Vec<usize>,
    /// Scheduler-side flight-recorder spans (queue wait, coalesce,
    /// dispatch, generation respawn) — recorded when `SPDNN_TRACE` is set.
    trace: Vec<Span>,
}

/// Persistent serving pool over the row-wise partitioned SpMM engine.
///
/// ```no_run
/// use spdnn::radixnet::{generate, RadixNetConfig};
/// use spdnn::serving::{PoolConfig, RankPool};
///
/// let net = generate(&RadixNetConfig::graph_challenge(1024, 12).unwrap());
/// let pool = RankPool::start(net, PoolConfig::default());
/// let b = 4;
/// let ticket = pool.submit(vec![0.0; 1024 * b], b);
/// let _logits = ticket.wait().expect("served");
/// let summary = pool.shutdown().unwrap();
/// assert!(summary.leaked_ranks.is_empty());
/// ```
pub struct RankPool {
    shared: Arc<SharedQueue>,
    stats: Arc<ServingStats>,
    scheduler: Mutex<Option<JoinHandle<SchedulerReport>>>,
    input_dim: usize,
    /// Requeue attempts granted to each submitted ticket
    /// ([`RecoveryConfig::retry_budget`]).
    retry_budget: u32,
}

impl RankPool {
    /// Spawn the pool over a contiguous nnz-balanced partition at
    /// `cfg.nranks` (zero partitioning latency at startup); rank threads
    /// and states are built immediately and reused for every request.
    pub fn start(net: SparseNet, cfg: PoolConfig) -> Self {
        let sp = ServingPlan::contiguous(&net.layers, cfg.nranks);
        Self::start_with_plan(net, sp, cfg)
    }

    /// Spawn the pool over a caller-chosen partition/plan bundle (e.g. a
    /// hypergraph partition). `cfg.nranks` is ignored in favour of the
    /// plan's rank count.
    pub fn start_with_plan(net: SparseNet, mut sp: ServingPlan, cfg: PoolConfig) -> Self {
        assert!(sp.nranks() > 0, "pool needs at least one rank");
        assert!(cfg.max_batch > 0, "max_batch must be positive");
        // Apply the config codec (both phases — serving is forward-only,
        // set for consistency), EXCEPT when the config carries the F32
        // default and the caller already tuned codecs on the plan: a
        // default config must not silently clobber per-layer choices.
        let plan_tuned = sp
            .plan
            .layers
            .iter()
            .any(|l| l.codec_fwd != Codec::F32 || l.codec_bwd != Codec::F32);
        if cfg.codec != Codec::F32 || !plan_tuned {
            sp.plan.set_codec(cfg.codec, cfg.codec);
        }
        let input_dim = net.input_dim();
        let output_dim = net.output_dim();
        let edges_per_col = net.total_nnz() as f64;
        let net = Arc::new(net);
        let sp = Arc::new(sp);
        let shared = Arc::new(SharedQueue::default());
        // Idle gaps saturate at a small multiple of the batch window when
        // entering the inter-arrival EWMA — one quiet period must not keep
        // the adaptive scheduler in skip-the-wait mode after load returns.
        shared.state.lock().unwrap().gap_clamp = Some(cfg.max_wait * GAP_CLAMP_MULT);
        let stats = Arc::new(ServingStats::new());
        let retry_budget = cfg.recovery.retry_budget;
        let sched_shared = Arc::clone(&shared);
        let sched_stats = Arc::clone(&stats);
        let handle = std::thread::Builder::new()
            .name("spdnn-pool-scheduler".to_string())
            .spawn(move || {
                scheduler_loop(
                    net,
                    sp,
                    cfg,
                    sched_shared,
                    sched_stats,
                    output_dim,
                    edges_per_col,
                )
            })
            .expect("failed to spawn pool scheduler");
        Self {
            shared,
            stats,
            scheduler: Mutex::new(Some(handle)),
            input_dim,
            retry_budget,
        }
    }

    /// Submit one `[n0 × b]` row-major batch (column j = input j). Returns
    /// immediately; block on or poll the ticket for the `[nL × b]` output.
    pub fn submit(&self, x0: Vec<f32>, b: usize) -> Ticket {
        self.submit_inner(x0, b, None)
    }

    /// [`RankPool::submit`] with a queue-wait SLO: if the scheduler
    /// reaches the request only after it has been queued longer than
    /// `slo`, the ticket fails with
    /// [`ServeError::DeadlineExceeded`] instead of being served late —
    /// under overload the pool sheds stale work rather than letting every
    /// queued request's latency grow without bound.
    pub fn submit_with_deadline(&self, x0: Vec<f32>, b: usize, slo: Duration) -> Ticket {
        self.submit_inner(x0, b, Some(slo))
    }

    fn submit_inner(&self, x0: Vec<f32>, b: usize, deadline: Option<Duration>) -> Ticket {
        assert!(b > 0, "batch must be non-empty");
        assert_eq!(
            x0.len(),
            self.input_dim * b,
            "input must be [n0 × b] row-major"
        );
        let (tx, rx) = channel();
        let now = Instant::now();
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.shutdown {
                drop(st);
                panic!("submit after pool shutdown");
            }
            st.note_arrival(now);
            st.queue.push_back(Pending {
                x0,
                b,
                tx,
                submitted: now,
                deadline,
                retries_left: self.retry_budget,
            });
        }
        self.shared.cv.notify_all();
        Ticket { rx }
    }

    /// Current counters: throughput, batching efficiency, latency
    /// percentiles.
    pub fn stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }

    /// Render the pool's live counters as Prometheus text exposition —
    /// the serving half of the unified [`MetricsRegistry`] interface
    /// (scrape-ready: every counter/gauge carries `# HELP`/`# TYPE`).
    pub fn prometheus(&self) -> String {
        let mut reg = MetricsRegistry::new();
        reg.record_serving(&self.stats.snapshot());
        reg.render()
    }

    /// Graceful shutdown: every already-queued request is still served,
    /// then the rank threads exit after a final message-leak check.
    /// Idempotent — returns `None` on the second call (also invoked by
    /// `Drop`).
    pub fn shutdown(&self) -> Option<PoolSummary> {
        let handle = self.scheduler.lock().unwrap().take()?;
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        let report = handle.join().expect("pool scheduler panicked");
        Some(PoolSummary {
            stats: self.stats.snapshot(),
            leaked_ranks: report.leaked_ranks,
            trace: report.trace,
        })
    }
}

impl Drop for RankPool {
    fn drop(&mut self) {
        // Never panic out of Drop (e.g. while unwinding a failing test):
        // a scheduler that itself died just loses its final leak report.
        let _ = catch_unwind(AssertUnwindSafe(|| self.shutdown()));
    }
}

/// Final report of a pool lifetime.
#[derive(Debug, Clone)]
pub struct PoolSummary {
    pub stats: StatsSnapshot,
    /// Ranks whose endpoints still held unconsumed messages at shutdown —
    /// empty for a healthy pool (the stress tests assert this).
    pub leaked_ranks: Vec<usize>,
    /// The scheduler's flight-recorder spans (category `pool`): queue
    /// wait, batch coalescing, dispatch, and generation respawns. Empty
    /// unless `SPDNN_TRACE` enabled tracing for this process.
    pub trace: Vec<Span>,
}

fn scheduler_loop(
    net: Arc<SparseNet>,
    sp: Arc<ServingPlan>,
    cfg: PoolConfig,
    shared: Arc<SharedQueue>,
    stats: Arc<ServingStats>,
    output_dim: usize,
    edges_per_col: f64,
) -> SchedulerReport {
    // The scheduler gets its own flight-recorder track (`u32::MAX` marks
    // "not a rank"); span sites cost two branches each when tracing is off.
    let mut tracer = Tracer::new(TraceMode::from_env(), u32::MAX);
    // Resolve the fault plan once: an explicit config plan wins, else the
    // process-wide SPDNN_FAULT plan, else no chaos at all. The watchdog
    // deadline follows the same precedence.
    let plan = cfg.faults.clone().or_else(fault::from_env);
    let watchdog = cfg
        .watchdog
        .or_else(|| plan.as_ref().and_then(|p| p.spec().watchdog()));
    let rec = cfg.recovery;
    let mut breaker = Breaker::new(rec.breaker_threshold, rec.breaker_cooldown);
    // Deterministic backoff jitter: keyed off the fault plan's seed so
    // chaos runs replay exactly; the constant fallback is arbitrary.
    let backoff_seed = plan.as_ref().map_or(0x00C0_FFEE, |p| p.spec().seed);
    let mut backoff = Backoff::new(rec.backoff_base, rec.backoff_cap, backoff_seed);
    let mut gen = spawn_generation(&net, &sp, cfg.mode, &plan, watchdog);
    loop {
        if !fail_fast_while_open(&shared, &stats, &mut breaker) {
            break; // shutdown arrived while the breaker was open
        }
        let Some(batch) = collect_batch(&shared, &cfg, &stats, &mut tracer) else {
            break;
        };
        let nreq = batch.len();
        let total_cols: usize = batch.iter().map(|p| p.b).sum();
        // chaos failpoint: scheduler-side dispatch delay (free roll)
        gen.observer.dispatch_delay_failpoint();
        let sp_dispatch = tracer.start();
        let sw = Instant::now();
        match dispatch(&gen, &batch) {
            Ok((rank_rows, raw_bytes, wire_bytes)) => {
                let service_secs = sw.elapsed().as_secs_f64();
                tracer.end(sp_dispatch, "dispatch", "pool", NO_LAYER, NO_CHUNK, wire_bytes);
                if breaker.state() != BreakerState::Closed || breaker.consecutive() > 0 {
                    stats.set_breaker_state(BreakerState::Closed.code());
                }
                breaker.on_success();
                backoff.reset();
                let out = assemble_outputs(output_dim, total_cols, &rank_rows);
                let done = Instant::now();
                // record before replying: a stats() read racing a just-woken
                // waiter must already see this batch's counters
                for p in &batch {
                    stats.record_latency(done.duration_since(p.submitted).as_secs_f64());
                }
                stats.record_batch(
                    nreq,
                    total_cols,
                    edges_per_col * total_cols as f64,
                    service_secs,
                );
                stats.record_wire(raw_bytes, wire_bytes);
                // de-interleave the fused columns back per request
                let mut off = 0usize;
                for p in &batch {
                    let mut slice = vec![0f32; output_dim * p.b];
                    for i in 0..output_dim {
                        let src = i * total_cols + off;
                        slice[i * p.b..(i + 1) * p.b]
                            .copy_from_slice(&out[src..src + p.b]);
                    }
                    off += p.b;
                    let _ = p.tx.send(Ok(slice));
                }
            }
            Err(failure) => {
                tracer.end(sp_dispatch, "dispatch", "pool", NO_LAYER, NO_CHUNK, 0);
                // classify the root cause for the recovery counters
                if fault::is_stall(&failure.message) {
                    stats.record_watchdog_trip();
                } else if fault::is_corrupt(&failure.message) {
                    stats.record_checksum_failure();
                }
                breaker.on_failure(Instant::now());
                stats.set_breaker_state(breaker.state().code());
                crate::log!(
                    Warn,
                    "pool generation poisoned by rank {} ({}); respawning",
                    failure.rank,
                    failure.message
                );
                // Triage the poisoned batch: every member is innocent (the
                // fault was environmental), so requeue those with retry
                // budget left — at the FRONT, preserving FIFO order — and
                // fail the rest with the typed root cause.
                let err = ServeError::from(failure);
                let (mut failed, mut retried) = (0usize, 0usize);
                {
                    let mut st = shared.state.lock().unwrap();
                    for mut p in batch.into_iter().rev() {
                        if p.retries_left > 0 {
                            p.retries_left -= 1;
                            st.queue.push_front(p);
                            retried += 1;
                        } else {
                            failed += 1;
                            let _ = p.tx.send(Err(err.clone()));
                        }
                    }
                }
                stats.record_dispatch_failure(failed, retried);
                // the fabric is poisoned — respawn the whole generation,
                // spacing consecutive respawns by the backoff schedule
                let sp_respawn = tracer.start();
                teardown(gen);
                std::thread::sleep(backoff.next_delay());
                gen = spawn_generation(&net, &sp, cfg.mode, &plan, watchdog);
                stats.record_respawn();
                tracer.end(sp_respawn, "respawn", "pool", NO_LAYER, NO_CHUNK, 0);
            }
        }
    }
    // graceful shutdown: stop the ranks, collect their final drain checks
    let nranks = gen.cmd_tx.len();
    for tx in &gen.cmd_tx {
        let _ = tx.send(RankCmd::Shutdown);
    }
    let mut leaked_ranks = Vec::new();
    for _ in 0..nranks {
        match gen.res_rx.recv() {
            Ok((_, Ok(_))) => {}
            Ok((rank, Err(_))) => leaked_ranks.push(rank),
            Err(_) => break,
        }
    }
    for h in gen.handles {
        let _ = h.join();
    }
    leaked_ranks.sort_unstable();
    SchedulerReport {
        leaked_ranks,
        trace: tracer.spans(),
    }
}

/// Circuit-breaker front gate of the scheduler loop. While the breaker is
/// open, every queued request is fast-failed with
/// [`ServeError::Unavailable`] — replied immediately, never dispatched
/// into the crash loop — and the scheduler sleeps in short condvar slices
/// until the cooldown elapses (the breaker half-opens and one trial batch
/// is admitted) or shutdown arrives. Returns `false` on shutdown; any
/// requests still queued then resolve to [`ServeError::Shutdown`] when
/// their reply channels drop.
fn fail_fast_while_open(
    shared: &SharedQueue,
    stats: &ServingStats,
    breaker: &mut Breaker,
) -> bool {
    if breaker.state() != BreakerState::Open {
        return true;
    }
    let mut st = shared.state.lock().unwrap();
    loop {
        // poll BEFORE draining: a trial request submitted just after the
        // cooldown elapsed must reach the half-open dispatch, not be
        // swept up with the fast-fails
        let now = Instant::now();
        if breaker.poll(now) != BreakerState::Open {
            stats.set_breaker_state(breaker.state().code());
            return true;
        }
        while let Some(p) = st.queue.pop_front() {
            stats.record_unavailable(1);
            let _ = p.tx.send(Err(ServeError::Unavailable {
                failures: breaker.consecutive(),
            }));
        }
        if st.shutdown {
            return false;
        }
        // short slices keep both the cooldown and shutdown responsive
        let slice = breaker
            .remaining_cooldown(now)
            .min(Duration::from_millis(50));
        let (guard, _) = shared.cv.wait_timeout(st, slice).unwrap();
        st = guard;
    }
}

/// Fail a request whose queue wait blew its SLO (load shedding) and count
/// it. The reply goes out while the scheduler still holds the queue lock —
/// an unbounded-channel send, never blocking.
fn shed(stats: &ServingStats, p: Pending, slo: Duration) {
    stats.record_shed(1);
    let waited = p.submitted.elapsed();
    let _ = p.tx.send(Err(ServeError::DeadlineExceeded { waited, slo }));
}

/// True if the request has waited past its deadline.
fn expired(p: &Pending) -> Option<Duration> {
    p.deadline.filter(|&slo| p.submitted.elapsed() > slo)
}

/// Pop the next micro-batch: block for the first request, then hold the
/// batch open — up to `max_batch` columns or the adaptive wait deadline —
/// coalescing FIFO-adjacent requests. Requests whose queue wait already
/// exceeds their SLO are shed on the spot instead of joining the batch.
/// `None` once the pool is shutting down and the queue is drained.
fn collect_batch(
    shared: &SharedQueue,
    cfg: &PoolConfig,
    stats: &ServingStats,
    tracer: &mut Tracer,
) -> Option<Vec<Pending>> {
    let sp_wait = tracer.start();
    let mut st = shared.state.lock().unwrap();
    let first = loop {
        if let Some(p) = st.queue.pop_front() {
            if let Some(slo) = expired(&p) {
                shed(stats, p, slo);
                continue;
            }
            break p;
        }
        if st.shutdown {
            return None;
        }
        st = shared.cv.wait(st).unwrap();
    };
    tracer.end(sp_wait, "queue.wait", "pool", NO_LAYER, NO_CHUNK, 0);
    let wait = if cfg.adaptive {
        effective_wait(cfg.max_wait, st.ewma_gap)
    } else {
        cfg.max_wait
    };
    let deadline = first.submitted + wait;
    let mut cols = first.b;
    let mut batch = vec![first];
    let sp_coalesce = tracer.start();
    while cols < cfg.max_batch {
        if let Some(front) = st.queue.front() {
            if expired(front).is_some() {
                let p = st.queue.pop_front().expect("front exists");
                let slo = p.deadline.expect("expired implies a deadline");
                shed(stats, p, slo);
                continue;
            }
            if cols + front.b <= cfg.max_batch {
                let p = st.queue.pop_front().expect("front exists");
                cols += p.b;
                batch.push(p);
                continue;
            }
            break; // head-of-line request doesn't fit; keep FIFO order
        }
        if st.shutdown {
            break; // drain fast, don't hold batches open
        }
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        let (guard, _) = shared.cv.wait_timeout(st, deadline - now).unwrap();
        st = guard;
    }
    tracer.end(sp_coalesce, "coalesce", "pool", NO_LAYER, NO_CHUNK, cols as u64);
    Some(batch)
}

/// Broadcast one fused job to every rank and collect their owned output
/// rows in rank order, plus the job's raw/wire payload byte totals over
/// all ranks. Any rank error fails the whole job with the most
/// informative failure — root causes preferred over secondary unwinds,
/// exactly like the one-shot engine's triage.
fn dispatch(
    gen: &Generation,
    batch: &[Pending],
) -> Result<(Vec<RankRows>, u64, u64), RankFailure> {
    let nranks = gen.cmd_tx.len();
    let total_cols: usize = batch.iter().map(|p| p.b).sum();
    let n0 = batch[0].x0.len() / batch[0].b;
    // interleave the per-request column blocks into one [n0 × B] matrix
    let mut x0 = vec![0f32; n0 * total_cols];
    for i in 0..n0 {
        let mut off = 0usize;
        for p in batch {
            let dst = i * total_cols + off;
            x0[dst..dst + p.b].copy_from_slice(&p.x0[i * p.b..(i + 1) * p.b]);
            off += p.b;
        }
    }
    let job = Arc::new(Job { x0, b: total_cols });
    for tx in &gen.cmd_tx {
        if tx.send(RankCmd::Run(Arc::clone(&job))).is_err() {
            return Err(RankFailure {
                rank: 0,
                message: "pool rank thread is gone".to_string(),
            });
        }
    }
    let mut outputs: Vec<Option<RankRows>> = (0..nranks).map(|_| None).collect();
    let (mut raw_bytes, mut wire_bytes) = (0u64, 0u64);
    let mut failure: Option<RankFailure> = None;
    for _ in 0..nranks {
        match gen.res_rx.recv() {
            Ok((rank, Ok(out))) => {
                raw_bytes += out.raw_bytes;
                wire_bytes += out.wire_bytes;
                outputs[rank] = Some(out.rows);
            }
            Ok((rank, Err(message))) => {
                let candidate = RankFailure { rank, message };
                let better = match &failure {
                    None => true,
                    Some(cur) => is_secondary(&cur.message) && !is_secondary(&candidate.message),
                };
                if better {
                    failure = Some(candidate);
                }
            }
            Err(_) => {
                return Err(failure.unwrap_or_else(|| RankFailure {
                    rank: 0,
                    message: "pool rank threads disconnected".to_string(),
                }))
            }
        }
    }
    match failure {
        Some(f) => Err(f),
        None => Ok((
            outputs
                .into_iter()
                .map(|o| o.expect("every rank reported"))
                .collect(),
            raw_bytes,
            wire_bytes,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::inference::infer_batch;
    use crate::radixnet::{generate, RadixNetConfig};
    use crate::util::Rng;

    fn net64() -> SparseNet {
        generate(&RadixNetConfig::graph_challenge(64, 3).expect("cfg"))
    }

    fn random_input(rng: &mut Rng, n: usize, b: usize) -> Vec<f32> {
        (0..n * b)
            .map(|_| if rng.gen_bool(0.3) { 1.0 } else { 0.0 })
            .collect()
    }

    #[test]
    fn pool_matches_serial_across_requests() {
        let net = net64();
        let pool = RankPool::start(
            net.clone(),
            PoolConfig {
                nranks: 3,
                max_batch: 8,
                max_wait: Duration::from_micros(200),
                adaptive: true,
                mode: ExecMode::Overlap,
                ..PoolConfig::default()
            },
        );
        let mut rng = Rng::new(11);
        for req in 0..6 {
            let b = 1 + (req % 4);
            let x0 = random_input(&mut rng, 64, b);
            let out = pool.submit(x0.clone(), b).wait().expect("served");
            let serial = infer_batch(&net, &x0, b);
            assert_eq!(out.len(), serial.len());
            for (a, s) in out.iter().zip(serial.iter()) {
                assert!((a - s).abs() < 1e-5, "req {req} b={b}");
            }
        }
        let summary = pool.shutdown().expect("first shutdown");
        assert!(summary.leaked_ranks.is_empty());
        assert_eq!(summary.stats.requests, 6);
        assert_eq!(summary.stats.failed_requests, 0);
        assert!(summary.stats.p50_secs > 0.0);
        assert!(pool.shutdown().is_none(), "shutdown is idempotent");
    }

    #[test]
    fn hypergraph_plan_pool_matches_serial() {
        use crate::partition::phases::{hypergraph_partition, PhaseConfig};
        let net = net64();
        let part = hypergraph_partition(&net.layers, &PhaseConfig::new(4));
        let sp = ServingPlan::from_partition(&net.layers, part);
        let pool = RankPool::start_with_plan(net.clone(), sp, PoolConfig::default());
        let mut rng = Rng::new(3);
        let b = 5;
        let x0 = random_input(&mut rng, 64, b);
        let out = pool.submit(x0.clone(), b).wait().expect("served");
        let serial = infer_batch(&net, &x0, b);
        for (a, s) in out.iter().zip(serial.iter()) {
            assert!((a - s).abs() < 1e-5);
        }
    }

    #[test]
    fn blocking_mode_pool_matches_serial() {
        // the measured baseline engine stays correct behind the pool too
        let net = net64();
        let pool = RankPool::start(
            net.clone(),
            PoolConfig {
                nranks: 3,
                max_batch: 8,
                max_wait: Duration::ZERO,
                adaptive: false,
                mode: ExecMode::Blocking,
                ..PoolConfig::default()
            },
        );
        let mut rng = Rng::new(19);
        for b in [1usize, 4, 7] {
            let x0 = random_input(&mut rng, 64, b);
            let out = pool.submit(x0.clone(), b).wait().expect("served");
            let serial = infer_batch(&net, &x0, b);
            for (a, s) in out.iter().zip(serial.iter()) {
                assert!((a - s).abs() < 1e-5, "b={b}");
            }
        }
        let summary = pool.shutdown().expect("shutdown");
        assert!(summary.leaked_ranks.is_empty());
    }
}
