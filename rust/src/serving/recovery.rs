//! Recovery policies of the serving pool: the respawn backoff schedule
//! and the availability circuit breaker.
//!
//! Both are plain, clock-parameterized state machines — the scheduler
//! thread passes `Instant`s in, nothing here reads the wall clock — so
//! the unit suites drive them with synthetic timestamps and stay fully
//! deterministic.
//!
//! **Backoff.** Consecutive generation respawns are spaced by truncated
//! exponential backoff with equal jitter: attempt *k* sleeps a uniform
//! draw from `[d/2, d]` where `d = min(base · 2ᵏ, cap)`. The jitter
//! breaks respawn synchronization; the deterministic seed keeps chaos
//! runs replayable. One successful dispatch resets the schedule.
//!
//! **Breaker.** After `threshold` consecutive generation failures the
//! breaker opens and the pool fast-fails requests with
//! [`crate::serving::ServeError::Unavailable`] instead of queueing them
//! behind a crash loop. After `cooldown` it half-opens: exactly one
//! trial batch is admitted — success closes the breaker, failure
//! reopens it for another cooldown.
//!
//! ```text
//!                 failure (consecutive == threshold)
//!      ┌────────┐ ───────────────────────────────────▶ ┌────────┐
//!      │ Closed │                                      │  Open  │
//!      └────────┘ ◀──────────┐              cooldown   └────────┘
//!        ▲    │ failure      │              elapsed        │
//!        │    ▼ (< threshold)│ success                     ▼
//!        │   stay Closed     │                         ┌──────────┐
//!        └───────────────────┴──────────────────────── │ HalfOpen │
//!                                      failure: reopen └──────────┘
//! ```

use crate::util::Rng;
use std::time::{Duration, Instant};

/// Knobs of the pool's failure-recovery pipeline, carried in
/// [`crate::serving::PoolConfig`].
#[derive(Clone, Copy, Debug)]
pub struct RecoveryConfig {
    /// Requeue attempts granted to each ticket: how many times an
    /// innocent request from a poisoned fused batch is retried on the
    /// respawned generation before it resolves to the typed error.
    pub retry_budget: u32,
    /// First respawn delay of the backoff schedule.
    pub backoff_base: Duration,
    /// Ceiling of the backoff schedule.
    pub backoff_cap: Duration,
    /// Consecutive generation failures that trip the circuit breaker.
    pub breaker_threshold: u32,
    /// How long an open breaker fast-fails before half-opening a trial.
    pub breaker_cooldown: Duration,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        RecoveryConfig {
            retry_budget: 2,
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(500),
            breaker_threshold: 5,
            breaker_cooldown: Duration::from_secs(1),
        }
    }
}

/// Truncated exponential backoff with equal jitter, seeded for
/// deterministic replay.
#[derive(Debug)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    attempt: u32,
    rng: Rng,
}

impl Backoff {
    /// A fresh schedule starting at `base`, capped at `cap`.
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Self {
        Backoff {
            base,
            cap,
            attempt: 0,
            rng: Rng::new(seed),
        }
    }

    /// The next delay: uniform in `[d/2, d]` for
    /// `d = min(base · 2^attempt, cap)`, then advance the attempt
    /// counter.
    pub fn next_delay(&mut self) -> Duration {
        let exp = (self.base.as_secs_f64() * 2f64.powi(self.attempt.min(62) as i32))
            .min(self.cap.as_secs_f64());
        self.attempt = self.attempt.saturating_add(1);
        Duration::from_secs_f64(exp / 2.0 + self.rng.gen_f64() * exp / 2.0)
    }

    /// Restart the schedule after a successful dispatch.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }

    /// Consecutive delays handed out since the last reset.
    pub fn attempt(&self) -> u32 {
        self.attempt
    }
}

/// Circuit-breaker states, in escalation order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Normal service.
    Closed,
    /// Cooldown elapsed; one trial generation is admitted.
    HalfOpen,
    /// Fast-failing: requests resolve to `Unavailable` immediately.
    Open,
}

impl BreakerState {
    /// Numeric gauge encoding for metrics: 0 closed, 1 half-open, 2 open.
    pub fn code(&self) -> u8 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::HalfOpen => 1,
            BreakerState::Open => 2,
        }
    }
}

/// The availability circuit breaker (see the module docs for the state
/// diagram). All transitions take the caller's `now`, so the machine is
/// testable with synthetic clocks.
#[derive(Debug)]
pub struct Breaker {
    threshold: u32,
    cooldown: Duration,
    consecutive: u32,
    state: BreakerState,
    opened_at: Option<Instant>,
}

impl Breaker {
    /// A closed breaker tripping after `threshold` consecutive failures
    /// (clamped to at least 1) and cooling down for `cooldown`.
    pub fn new(threshold: u32, cooldown: Duration) -> Self {
        Breaker {
            threshold: threshold.max(1),
            cooldown,
            consecutive: 0,
            state: BreakerState::Closed,
            opened_at: None,
        }
    }

    /// Current state (without advancing the cooldown — see
    /// [`Breaker::poll`]).
    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// Consecutive generation failures observed since the last success.
    pub fn consecutive(&self) -> u32 {
        self.consecutive
    }

    /// A dispatch succeeded: close and forget the failure streak.
    pub fn on_success(&mut self) {
        self.consecutive = 0;
        self.state = BreakerState::Closed;
        self.opened_at = None;
    }

    /// A generation failed at `now`. A half-open trial failure reopens
    /// immediately; a closed breaker opens once the streak reaches the
    /// threshold.
    pub fn on_failure(&mut self, now: Instant) {
        self.consecutive = self.consecutive.saturating_add(1);
        match self.state {
            BreakerState::HalfOpen => {
                self.state = BreakerState::Open;
                self.opened_at = Some(now);
            }
            BreakerState::Closed if self.consecutive >= self.threshold => {
                self.state = BreakerState::Open;
                self.opened_at = Some(now);
            }
            _ => {}
        }
    }

    /// Advance the cooldown: an open breaker whose cooldown has elapsed
    /// at `now` half-opens. Returns the (possibly updated) state.
    pub fn poll(&mut self, now: Instant) -> BreakerState {
        if self.state == BreakerState::Open {
            if let Some(opened) = self.opened_at {
                if now.duration_since(opened) >= self.cooldown {
                    self.state = BreakerState::HalfOpen;
                }
            }
        }
        self.state
    }

    /// Time left before an open breaker half-opens; zero otherwise.
    pub fn remaining_cooldown(&self, now: Instant) -> Duration {
        match (self.state, self.opened_at) {
            (BreakerState::Open, Some(opened)) => {
                self.cooldown.saturating_sub(now.duration_since(opened))
            }
            _ => Duration::ZERO,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_schedule_doubles_with_equal_jitter_then_caps() {
        let base = Duration::from_millis(10);
        let cap = Duration::from_millis(500);
        let mut bo = Backoff::new(base, cap, 42);
        let mut expected = 0.010f64;
        for attempt in 0..12 {
            let d = bo.next_delay().as_secs_f64();
            let e = expected.min(0.5);
            assert!(
                d >= e / 2.0 - 1e-9 && d <= e + 1e-9,
                "attempt {attempt}: delay {d} outside [{}, {e}]",
                e / 2.0
            );
            expected *= 2.0;
        }
        assert_eq!(bo.attempt(), 12);
        bo.reset();
        assert_eq!(bo.attempt(), 0);
        let d = bo.next_delay().as_secs_f64();
        assert!(d <= 0.010 + 1e-9, "reset must restart at the base delay");
    }

    #[test]
    fn backoff_is_deterministic_per_seed() {
        let mk = |seed| {
            let mut bo = Backoff::new(Duration::from_millis(5), Duration::from_millis(80), seed);
            (0..8).map(|_| bo.next_delay()).collect::<Vec<_>>()
        };
        assert_eq!(mk(7), mk(7));
        assert_ne!(mk(7), mk(8));
    }

    #[test]
    fn breaker_trips_after_threshold_consecutive_failures() {
        let t0 = Instant::now();
        let mut br = Breaker::new(3, Duration::from_secs(1));
        assert_eq!(br.state(), BreakerState::Closed);
        br.on_failure(t0);
        br.on_failure(t0);
        assert_eq!(br.state(), BreakerState::Closed, "below threshold stays closed");
        assert_eq!(br.consecutive(), 2);
        br.on_failure(t0);
        assert_eq!(br.state(), BreakerState::Open);
        assert_eq!(br.state().code(), 2);
        assert_eq!(br.remaining_cooldown(t0), Duration::from_secs(1));
    }

    #[test]
    fn success_resets_the_streak() {
        let t0 = Instant::now();
        let mut br = Breaker::new(3, Duration::from_secs(1));
        br.on_failure(t0);
        br.on_failure(t0);
        br.on_success();
        assert_eq!(br.consecutive(), 0);
        br.on_failure(t0);
        br.on_failure(t0);
        assert_eq!(br.state(), BreakerState::Closed, "streak must restart after success");
    }

    #[test]
    fn open_half_opens_after_cooldown_and_trial_outcome_decides() {
        let t0 = Instant::now();
        let cooldown = Duration::from_secs(1);
        let mut br = Breaker::new(1, cooldown);
        br.on_failure(t0);
        assert_eq!(br.state(), BreakerState::Open);
        assert_eq!(br.poll(t0 + Duration::from_millis(500)), BreakerState::Open);
        assert_eq!(
            br.remaining_cooldown(t0 + Duration::from_millis(400)),
            Duration::from_millis(600)
        );
        assert_eq!(br.poll(t0 + cooldown), BreakerState::HalfOpen);
        assert_eq!(br.state().code(), 1);
        // trial failure reopens for a fresh cooldown
        br.on_failure(t0 + cooldown);
        assert_eq!(br.state(), BreakerState::Open);
        assert_eq!(br.remaining_cooldown(t0 + cooldown), cooldown);
        // next trial succeeds: closed, streak forgotten
        assert_eq!(br.poll(t0 + cooldown + cooldown), BreakerState::HalfOpen);
        br.on_success();
        assert_eq!(br.state(), BreakerState::Closed);
        assert_eq!(br.consecutive(), 0);
        assert_eq!(br.remaining_cooldown(t0), Duration::ZERO);
    }

    #[test]
    fn zero_threshold_clamps_to_one() {
        let t0 = Instant::now();
        let mut br = Breaker::new(0, Duration::from_secs(1));
        br.on_failure(t0);
        assert_eq!(br.state(), BreakerState::Open);
    }
}
