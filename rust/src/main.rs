//! spdnn CLI — leader entrypoint for every experiment and workload.
//!
//! ```text
//! spdnn table1     [--neurons 1024,4096] [--parts 4,8,16,32] [--layers 24] [--full]
//! spdnn scaling    [--neurons 1024] [--parts 32,64,128,256,512] [--layers 24] [--full]
//! spdnn breakdown  [--neurons 1024] [--parts 32,128,512] [--layers 24] [--full]
//! spdnn throughput [--neurons 1024,4096] [--layers 24] [--ranks 128] [--batch 64] [--full]
//! spdnn ptimes     [--neurons 1024] [--parts 32,64,128] [--layers 24] [--full]
//! spdnn ablate     [--neurons 1024] [--parts 8,32] [--layers 24]
//! spdnn train      [--neurons 1024] [--layers 12] [--ranks 4] [--steps 100] [--eta 0.01] [--batch 1] [--codec f32|f16|int8] [--replicas R]
//! spdnn replica    [--neurons 256] [--layers 8] [--ranks 2] [--batch 4] [--epochs 3] [--samples 64]
//!                  [--groups 1,2,4] [--modes overlap,pipelined] [--codecs f32,int8] [--out BENCH_replica.json]
//! spdnn infer      [--neurons 1024] [--layers 12] [--ranks 4] [--batch 64] [--method h|r] [--mode overlap] [--codec f32|f16|int8]
//! spdnn codec      [--neurons 1024] [--layers 12] [--ranks 4] [--steps 200] [--eta 0.1]
//! spdnn partition  [--neurons 1024] [--layers 12] [--ranks 8]
//! spdnn graphchallenge [--neurons 1024] [--layers 32] [--ranks 4] [--batch 64] [--inputs 256]
//!                  [--modes blocking,overlap,pipelined] [--codecs f32,f16] [--no-pool]
//!                  [--out BENCH_graphchallenge.json] [--full]
//! spdnn trace      [--neurons 1024] [--layers 24] [--ranks 4] [--batch 16] [--passes 8]
//!                  [--mode pipelined] [--codec f32] [--capacity 65536] [--out TRACE_<mode>.json]
//! spdnn chaos      [--seed 42] [--requests 200] [--ranks 4] [--neurons 64] [--layers 3]
//!                  [--budget 12] [--retries 3] [--mode pipelined] [--out BENCH_chaos.json]
//! spdnn check      [--seed 7] [--no-live] [--out BENCH_check.json]
//! spdnn calibrate
//! ```
//!
//! `--full` switches to the paper's full grid (slow on one core; for
//! `graphchallenge` it streams the challenge's 60 000 inputs). The wire
//! codec also reads the `SPDNN_CODEC` env var when `--codec` is absent;
//! `train` reads `SPDNN_REPLICAS` when `--replicas` is absent and routes
//! through the replica-group drivers when R > 1 (`docs/TRAINING.md`).
//! `replica` sweeps the replica-group scaling harness and writes
//! `BENCH_replica.json` (enforced bars under `SPDNN_ENFORCE=1`).
//! `trace` writes Chrome trace-event JSON (open in Perfetto or
//! `chrome://tracing`) with span coverage and a replay-drift report under
//! the `"spdnn"` key. See the README's CLI reference section for the
//! shared flags, and `docs/OBSERVABILITY.md` for `SPDNN_TRACE`/`SPDNN_LOG`.

// The CLI is a separate crate root from the library: repeat the library's
// policy that `unsafe` lives only in `sparse::csr`.
#![deny(unsafe_code)]

use spdnn::comm::netmodel::ComputeModel;
use spdnn::comm::Codec;
use spdnn::coordinator::minibatch::train_minibatch_with_plan;
use spdnn::coordinator::sgd::{infer_with_plan_mode, run_with_plan};
use spdnn::coordinator::ExecMode;
use spdnn::data::synthetic_mnist;
use spdnn::experiments::{
    self, ablation, chaos, fig4_scaling, fig5_breakdown, graphchallenge, table1, table2, table3,
    trace, Method,
};
use spdnn::partition::metrics::PartitionMetrics;
use spdnn::partition::CommPlan;
use spdnn::radixnet::{generate, RadixNetConfig};
use spdnn::util::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help")
        .to_string();
    match cmd.as_str() {
        "table1" => cmd_table1(&args),
        "scaling" => cmd_scaling(&args),
        "breakdown" => cmd_breakdown(&args),
        "throughput" => cmd_throughput(&args),
        "ptimes" => cmd_ptimes(&args),
        "ablate" => cmd_ablate(&args),
        "codec" => cmd_codec(&args),
        "train" => cmd_train(&args),
        "replica" => cmd_replica(&args),
        "infer" => cmd_infer(&args),
        "partition" => cmd_partition(&args),
        "graphchallenge" => cmd_graphchallenge(&args),
        "trace" => cmd_trace(&args),
        "chaos" => cmd_chaos(&args),
        "check" => cmd_check(&args),
        "calibrate" => cmd_calibrate(),
        _ => help(),
    }
}

fn help() {
    println!("spdnn — Partitioning Sparse DNNs (ICS'21) reproduction");
    println!("experiments: table1 | scaling | breakdown | throughput | ptimes | ablate | codec");
    println!(
        "workloads:   train | replica | infer | partition | graphchallenge | trace | chaos | \
         check | calibrate"
    );
    println!("see `rust/src/main.rs` header or README.md for flags");
}

fn neurons_list(args: &Args, full: &[usize], small: &[usize]) -> Vec<usize> {
    if args.has("neurons") {
        args.get_usize_list("neurons", small)
    } else if args.get_bool("full", false) {
        full.to_vec()
    } else {
        small.to_vec()
    }
}

fn parts_list(args: &Args, full: &[usize], small: &[usize]) -> Vec<usize> {
    if args.has("parts") {
        args.get_usize_list("parts", small)
    } else if args.get_bool("full", false) {
        full.to_vec()
    } else {
        small.to_vec()
    }
}

fn layers_of(args: &Args) -> usize {
    args.get_usize(
        "layers",
        if args.get_bool("full", false) { 120 } else { 24 },
    )
}

fn cmd_table1(args: &Args) {
    let ns = neurons_list(args, &[1024, 4096, 16384, 65536], &[1024, 4096]);
    let ps = parts_list(args, &[32, 64, 128, 256, 512], &[4, 8, 16, 32]);
    let layers = layers_of(args);
    let seed = args.get_u64("seed", 1);
    println!("# Table 1 — volume/messages/imbalance (L={layers})");
    for n in ns {
        let rows = table1::run(n, layers, &ps, seed);
        println!("{}", table1::render(&rows));
    }
}

fn comp_model(args: &Args) -> ComputeModel {
    if args.get_bool("no-calibrate", false) {
        ComputeModel::haswell_defaults()
    } else {
        ComputeModel::calibrate()
    }
}

fn cmd_scaling(args: &Args) {
    let ns = neurons_list(args, &[1024, 4096, 16384, 65536], &[1024]);
    let ps = parts_list(args, &[32, 64, 128, 256, 512], &[8, 16, 32, 64, 128]);
    let layers = layers_of(args);
    let comp = comp_model(args);
    println!("# Figure 4 — strong scaling (simulated, L={layers})");
    for n in ns {
        let pts = fig4_scaling::run(n, layers, &ps, comp, args.get_u64("seed", 1));
        println!("{}", fig4_scaling::render(n, &pts));
    }
}

fn cmd_breakdown(args: &Args) {
    let ns = neurons_list(args, &[16384, 65536], &[1024]);
    let ps = parts_list(args, &[32, 128, 512], &[8, 32, 128]);
    let layers = layers_of(args);
    let comp = comp_model(args);
    println!("# Figure 5 — time breakdown (simulated, L={layers})");
    for n in ns {
        let bars = fig5_breakdown::run(n, layers, &ps, comp, args.get_u64("seed", 1));
        println!("{}", fig5_breakdown::render(n, &bars));
    }
}

fn cmd_throughput(args: &Args) {
    let ns = neurons_list(args, &[1024, 4096, 16384, 65536], &[1024, 4096]);
    let layers = layers_of(args);
    let cfg = table2::Config {
        nparts: args.get_usize("ranks", 128),
        batch: args.get_usize("batch", 64),
        inputs: args.get_usize(
            "inputs",
            if args.get_bool("full", false) {
                60_000
            } else {
                4096
            },
        ),
        gb_sample: args.get_usize("gb-sample", 128),
    };
    let comp = comp_model(args);
    println!(
        "# Table 2 — inference throughput (edges/s), H-SpFF P={} vs GB 16-core node",
        cfg.nparts
    );
    let rows: Vec<_> = ns
        .into_iter()
        .map(|n| table2::run(n, layers, &cfg, comp, args.get_u64("seed", 1)))
        .collect();
    println!("{}", table2::render(&rows));
}

fn cmd_ptimes(args: &Args) {
    let ns = neurons_list(args, &[1024, 4096, 16384, 65536], &[1024]);
    let ps = parts_list(args, &[32, 64, 128, 256, 512], &[8, 16, 32]);
    let layers = layers_of(args);
    println!("# Table 3 — partitioning times (s, L={layers})");
    for n in ns {
        let rows = table3::run(n, layers, &ps, args.get_u64("seed", 1));
        println!("{}", table3::render(&rows));
    }
}

/// The wire codec: `--codec f32|f16|int8`, falling back to the
/// `SPDNN_CODEC` env var, defaulting to lossless f32.
fn codec_of(args: &Args) -> Codec {
    let spec = args
        .get("codec")
        .map(|s| s.to_string())
        .or_else(|| std::env::var("SPDNN_CODEC").ok())
        .unwrap_or_else(|| "f32".to_string());
    Codec::parse(&spec)
        .unwrap_or_else(|| panic!("unknown codec '{spec}' (expected f32 | f16 | int8)"))
}

fn cmd_codec(args: &Args) {
    let n = args.get_usize("neurons", 1024);
    let layers = args.get_usize("layers", 12);
    let ranks = args.get_usize("ranks", 4);
    let steps = args.get_usize("steps", 200);
    let eta = args.get_f32("eta", 0.1);
    println!(
        "# Codec ablation — digits SGD convergence vs bytes-on-wire \
         (N={n} L={layers} P={ranks}, {steps} steps)"
    );
    let rows = ablation::codec_convergence(n, layers, ranks, steps, eta, args.get_u64("seed", 7));
    println!("{}", ablation::render_codec(n, ranks, &rows));
}

fn cmd_ablate(args: &Args) {
    let ns = neurons_list(args, &[1024, 4096], &[1024]);
    let ps = parts_list(args, &[8, 32, 128], &[8, 32]);
    let layers = layers_of(args);
    println!("# Ablation — fixed-vertex chaining vs independent vs random (L={layers})");
    for n in ns {
        for &p in &ps {
            let rows = ablation::run(n, layers, p, args.get_u64("seed", 1));
            println!("{}", ablation::render(n, p, &rows));
        }
    }
}

fn cmd_train(args: &Args) {
    let n = args.get_usize("neurons", 1024);
    let layers = args.get_usize("layers", 12);
    let ranks = args.get_usize("ranks", 4);
    let steps = args.get_usize("steps", 100);
    let eta = args.get_f32("eta", 0.01);
    let side = (n as f64).sqrt() as usize;
    assert_eq!(side * side, n, "neurons must be a square for MNIST input");

    let net = generate(&RadixNetConfig::graph_challenge(n, layers).expect("size"));
    let structure = net.layers.clone();
    let method = match args.get_str("method", "h").as_str() {
        "r" | "random" => Method::Random,
        _ => Method::Hypergraph,
    };
    spdnn::log!(
        Info,
        "partitioning N={n} L={layers} into {ranks} ranks ({})...",
        method.label()
    );
    let part = experiments::partition_with(&structure, method, ranks, 1);
    let m = PartitionMetrics::compute(&structure, &part);
    spdnn::log!(
        Info,
        "partition: avg vol {:.1} Kwords/iter, imb {:.3}",
        m.avg_volume() / 1e3,
        m.comp_imbalance()
    );

    let data = synthetic_mnist(side, steps, 7);
    let inputs: Vec<Vec<f32>> = data.samples.iter().map(|s| s.pixels.clone()).collect();
    let targets: Vec<Vec<f32>> = (0..steps).map(|i| data.target(i, n)).collect();
    let batch = args.get_usize("batch", 1);
    let codec = codec_of(args);
    let plan = CommPlan::build_with_codec(&structure, &part, codec, codec);
    let groups = args.get_usize("replicas", spdnn::replica::replicas_from_env());
    if groups > 1 {
        // hybrid data×model parallelism: R replica groups of `ranks` each,
        // cross-group gradients ring-all-reduced under `codec` (+EF when
        // lossy) — see docs/TRAINING.md
        let rcfg = spdnn::replica::ReplicaConfig {
            groups,
            batch: batch.max(1),
            eta,
            epochs: 1,
            mode: ExecMode::Overlap,
            codec,
            scope: spdnn::runtime::parallel::FaultScope::Env,
        };
        let run =
            spdnn::replica::train_replicas_with_plan(&net, &part, &plan, &inputs, &targets, &rcfg);
        for (i, l) in run.losses.iter().enumerate() {
            if i % 10 == 0 || i + 1 == run.losses.len() {
                println!("step {i:>5}  loss {l:.6}  (effective batch {})", groups * batch.max(1));
            }
        }
        let wire = |fabrics: &[Vec<spdnn::comm::FabricStats>]| -> u64 {
            fabrics.iter().flatten().map(|st| st.sent_wire_bytes).sum()
        };
        println!(
            "R={groups} groups x {ranks} ranks, codec {}: {:.1} KB intra-group, \
             {:.1} KB inter-group (all-reduce) on the wire",
            codec.label(),
            wire(&run.intra) as f64 / 1e3,
            wire(&run.inter) as f64 / 1e3
        );
        return;
    }
    let run = if batch > 1 {
        // §5.1 minibatch SpMM variant
        train_minibatch_with_plan(&net, &part, &plan, &inputs, &targets, batch, eta, 1)
    } else {
        run_with_plan(&net, &part, &plan, &inputs, &targets, eta, 1)
    };
    for (i, l) in run.losses.iter().enumerate() {
        if i % 10 == 0 || i + 1 == run.losses.len() {
            println!("step {i:>5}  loss {l:.6}");
        }
    }
    println!("per-rank sent (words, msgs): {:?}", run.sent);
    println!(
        "codec {}: {:.1} KB on the wire",
        codec.label(),
        run.sent.iter().map(|&(w, _)| w).sum::<u64>() as f64 * 4.0 / 1e3
    );
}

/// `spdnn replica` — the replica-group weak/strong-scaling harness
/// (`experiments::replica`): digits SGD at R ∈ `--groups` replica groups
/// per engine per gradient codec, written to `BENCH_replica.json`;
/// `SPDNN_ENFORCE=1` turns the scaling/compression/loss bars into hard
/// failures (the CI bench-smoke path uses `SPDNN_SECTION=replica`).
fn cmd_replica(args: &Args) {
    let mut cfg = experiments::replica::ReplicaBenchConfig {
        neurons: args.get_usize("neurons", 256),
        layers: args.get_usize("layers", 8),
        ranks: args.get_usize("ranks", 2),
        batch: args.get_usize("batch", 4),
        epochs: args.get_usize("epochs", 3),
        samples: args.get_usize("samples", 64),
        eta: args.get_f32("eta", 0.2),
        seed: args.get_u64("seed", 42),
        groups: args.get_usize_list("groups", &[1, 2, 4]),
        ..Default::default()
    };
    if let Some(spec) = args.get("modes") {
        cfg.modes = spec
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                ExecMode::from_name(s).unwrap_or_else(|| panic!("unknown mode '{s}' in --modes"))
            })
            .collect();
    }
    if let Some(spec) = args.get("codecs") {
        cfg.codecs = spec
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| Codec::parse(s).unwrap_or_else(|| panic!("unknown codec '{s}' in --codecs")))
            .collect();
    }
    println!(
        "# Replica-group scaling — N={} L={} at {} ranks/group, b={} x {} epochs, R in {:?}",
        cfg.neurons, cfg.layers, cfg.ranks, cfg.batch, cfg.epochs, cfg.groups
    );
    let rep = experiments::replica::run(&cfg);
    println!("{}", experiments::replica::render(&rep));
    let json = experiments::replica::to_json(&rep);
    let out = args.get_str("out", "BENCH_replica.json");
    std::fs::write(&out, &json).unwrap_or_else(|e| panic!("write {out}: {e}"));
    println!("wrote {out}: {json}");
    if std::env::var("SPDNN_ENFORCE").is_ok() {
        experiments::replica::enforce(&rep);
        println!("enforced bars passed: scaling, gradient compression, EF loss parity");
    }
}

fn cmd_infer(args: &Args) {
    let n = args.get_usize("neurons", 1024);
    let layers = args.get_usize("layers", 12);
    let ranks = args.get_usize("ranks", 4);
    let b = args.get_usize("batch", 64);
    let side = (n as f64).sqrt() as usize;
    let net = generate(&RadixNetConfig::graph_challenge(n, layers).expect("size"));
    let part = experiments::partition_with(&net.layers, Method::Hypergraph, ranks, 1);
    let codec = codec_of(args);
    let plan = CommPlan::build_with_codec(&net.layers, &part, codec, codec);
    let data = synthetic_mnist(side, b, 11);
    let (x0, b) = data.pack_batch(0, b);
    let mode = mode_of(args);
    let sw = spdnn::util::Stopwatch::start();
    let (out, sent) = infer_with_plan_mode(&net, &part, &plan, &x0, b, mode);
    let secs = sw.elapsed_secs();
    let edges = net.total_nnz() as f64 * b as f64;
    println!(
        "batch {b} ({} engine): {:.3}s live ({:.3e} edges/s 1-core), output dim {}",
        mode.label(),
        secs,
        edges / secs,
        out.len()
    );
    println!("per-rank (words, msgs): {sent:?}");
    println!(
        "codec {}: {:.1} KB on the wire (plan predicts {:.1} KB)",
        codec.label(),
        sent.iter().map(|&(w, _)| w).sum::<u64>() as f64 * 4.0 / 1e3,
        plan.fwd_wire_bytes(b, 0) as f64 / 1e3
    );
}

/// The execution engine: `--mode blocking|overlap|pipelined`, defaulting
/// to the one-shot drivers' overlap engine.
fn mode_of(args: &Args) -> ExecMode {
    let spec = args.get_str("mode", "overlap");
    ExecMode::from_name(&spec).unwrap_or_else(|| {
        panic!("unknown mode '{spec}' (expected blocking | overlap | pipelined)")
    })
}

fn cmd_graphchallenge(args: &Args) {
    let full = args.get_bool("full", false);
    let mut cfg = graphchallenge::GcConfig {
        neurons: args.get_usize("neurons", 1024),
        layers: args.get_usize("layers", 32),
        ranks: args.get_usize_list("ranks", &[4]),
        batch: args.get_usize("batch", 64),
        inputs: args.get_usize("inputs", if full { 60_000 } else { 256 }),
        pool: !args.get_bool("no-pool", false),
        seed: args.get_u64("seed", 0x6C),
        ..graphchallenge::GcConfig::default()
    };
    if let Some(spec) = args.get("modes") {
        cfg.modes = spec
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                ExecMode::from_name(s).unwrap_or_else(|| panic!("unknown mode '{s}' in --modes"))
            })
            .collect();
    }
    if let Some(spec) = args.get("codecs") {
        cfg.codecs = spec
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| Codec::parse(s).unwrap_or_else(|| panic!("unknown codec '{s}' in --codecs")))
            .collect();
    } else if args.has("codec") || std::env::var("SPDNN_CODEC").is_ok() {
        cfg.codecs = vec![codec_of(args)];
    }
    let net_cfg =
        spdnn::radixnet::RadixNetConfig::graph_challenge_inference(cfg.neurons, cfg.layers)
            .unwrap_or_else(|| panic!("unsupported neuron count {}", cfg.neurons));
    println!(
        "# Graph Challenge — RadixNet N={} L={} ({} edges), {} inputs × b={}",
        cfg.neurons,
        cfg.layers,
        net_cfg.total_edges(),
        cfg.inputs,
        cfg.batch
    );
    let rep = graphchallenge::run(&cfg);
    println!("{}", graphchallenge::render(&rep));
    let json = graphchallenge::to_json(&rep);
    let out = args.get_str("out", "BENCH_graphchallenge.json");
    std::fs::write(&out, &json).unwrap_or_else(|e| panic!("write {out}: {e}"));
    println!("wrote {out}: {json}");
}

fn cmd_trace(args: &Args) {
    let mode_spec = args.get_str("mode", "pipelined");
    let mode = ExecMode::from_name(&mode_spec).unwrap_or_else(|| {
        panic!("unknown mode '{mode_spec}' (expected blocking | overlap | pipelined)")
    });
    let cfg = trace::TraceConfig {
        neurons: args.get_usize("neurons", 1024),
        layers: args.get_usize("layers", 24),
        ranks: args.get_usize("ranks", 4),
        batch: args.get_usize("batch", 16),
        passes: args.get_usize("passes", 8),
        mode,
        codec: codec_of(args),
        capacity: args.get_usize("capacity", spdnn::obs::DEFAULT_TRACE_CAPACITY),
        calibrate: !args.get_bool("no-calibrate", false),
    };
    let rep = trace::run(&cfg);
    println!("{}", trace::render(&rep));
    let out = args.get_str("out", &format!("TRACE_{}.json", rep.mode));
    std::fs::write(&out, &rep.json).unwrap_or_else(|e| panic!("write {out}: {e}"));
    println!(
        "wrote {out} ({} spans) — open in Perfetto or chrome://tracing",
        rep.spans
    );
}

fn cmd_chaos(args: &Args) {
    let mut cfg = chaos::ChaosConfig {
        neurons: args.get_usize("neurons", 64),
        layers: args.get_usize("layers", 3),
        ranks: args.get_usize("ranks", 4),
        requests: args.get_usize("requests", 200),
        mode: ExecMode::from_name(&args.get_str("mode", "pipelined"))
            .unwrap_or_else(|| panic!("unknown mode (expected blocking | overlap | pipelined)")),
        retry_budget: args.get_usize("retries", 3) as u32,
        ..chaos::ChaosConfig::default()
    };
    cfg.spec.seed = args.get_u64("seed", cfg.spec.seed);
    cfg.spec.budget = args.get_u64("budget", cfg.spec.budget);
    println!(
        "# Chaos smoke — N={} L={} on a {}-rank pool: {} requests, fault seed {}, \
         budget {} (panic {:.1}% / stall {:.1}% / flip {:.1}% / drop {:.1}%)",
        cfg.neurons,
        cfg.layers,
        cfg.ranks,
        cfg.requests,
        cfg.spec.seed,
        cfg.spec.budget,
        cfg.spec.panic_p * 100.0,
        cfg.spec.stall_p * 100.0,
        cfg.spec.flip_p * 100.0,
        cfg.spec.drop_p * 100.0
    );
    let rep = chaos::run(&cfg);
    println!("{}", chaos::render(&rep));
    let json = chaos::to_json(&rep);
    let out = args.get_str("out", "BENCH_chaos.json");
    std::fs::write(&out, &json).unwrap_or_else(|e| panic!("write {out}: {e}"));
    println!("wrote {out}: {json}");
    if std::env::var("SPDNN_ENFORCE").is_ok() {
        chaos::enforce(&rep);
        println!("enforced bars passed: full resolution, bounded respawns, clean tail");
    }
}

fn cmd_partition(args: &Args) {
    let n = args.get_usize("neurons", 1024);
    let layers = args.get_usize("layers", 12);
    let ranks = args.get_usize("ranks", 8);
    let structure = experiments::structure_for(n, layers);
    for method in [Method::Hypergraph, Method::Random] {
        let sw = spdnn::util::Stopwatch::start();
        let part = experiments::partition_with(&structure, method, ranks, 1);
        let secs = sw.elapsed_secs();
        let m = PartitionMetrics::compute(&structure, &part);
        println!(
            "{}: {:.2}s | vol avg {:.1}K max {:.1}K | msgs avg {:.2}K | imb {:.3}",
            method.label(),
            secs,
            m.avg_volume() / 1e3,
            m.max_volume() / 1e3,
            m.avg_msgs() / 1e3,
            m.comp_imbalance()
        );
    }
}

/// `spdnn check` — the static plan verifier (see `docs/ANALYSIS.md`).
/// Runs [`spdnn::analysis::check_builtin_matrix`] over every built-in
/// configuration (nets × partitions × engine modes × codecs), the
/// replica-ring all-reduce matrix ([`spdnn::analysis::check_replica_matrix`],
/// `R...` codes), plus the trace-span taxonomy conformance checks, writes
/// the JSON report array to `--out`, and exits nonzero if any violation
/// was found. `--no-live` skips the traced micro-runs (they spawn rank
/// threads).
fn cmd_check(args: &Args) {
    use spdnn::analysis::{self, taxonomy, CheckReport};

    let seed = args.get_u64("seed", 7);
    let mut reports = analysis::check_builtin_matrix(seed);
    reports.extend(analysis::check_replica_matrix());
    let mut tax = Vec::new();
    taxonomy::check_doc(&mut tax);
    if !args.has("no-live") {
        taxonomy::check_live_spans(&mut tax);
    }
    reports.push(CheckReport {
        config: "taxonomy (doc table + live engine spans)".to_string(),
        layers: 0,
        nparts: 0,
        batch: 0,
        transfers: 0,
        messages: 0,
        wire_bytes: 0,
        violations: tax,
    });

    let mut failed = 0usize;
    for r in &reports {
        if r.ok() {
            println!("[ok  ] {}", r.config);
        } else {
            failed += 1;
            print!("{}", r.render());
        }
    }
    let json: Vec<String> = reports.iter().map(|r| r.to_json()).collect();
    let json = format!("[{}]", json.join(","));
    let out = args.get_str("out", "BENCH_check.json");
    std::fs::write(&out, &json).unwrap_or_else(|e| panic!("write {out}: {e}"));
    println!(
        "checked {} configurations, {failed} failed; wrote {out}",
        reports.len()
    );
    if failed > 0 {
        std::process::exit(1);
    }
}

fn cmd_calibrate() {
    let c = ComputeModel::calibrate();
    println!("spmv   {:.3e} s/nnz", c.spmv_per_nnz);
    println!("spmv_t {:.3e} s/nnz", c.spmvt_per_nnz);
    println!("update {:.3e} s/nnz", c.update_per_nnz);
    println!("elem   {:.3e} s/elem", c.elem);
}
