//! Streamed RadixNet generation.
//!
//! Each layer is assembled row-by-row directly into its final CSR arrays
//! through [`CsrStream`], with the exact entry count reserved up front —
//! generating a multi-million-edge Graph Challenge network never
//! materializes a COO copy, so peak RSS is essentially the finished
//! model. The output is bit-identical to the historical COO-based path:
//! the RNG draw order (per-layer permutation first, then weights in
//! row-major neighbor order) and the per-row column sort are unchanged
//! (`tests/radixnet_generator.rs` pins this against an in-test COO
//! rebuild).

use super::topology::{stage_row_base, strides};
use super::RadixNetConfig;
use crate::dnn::SparseNet;
use crate::sparse::{Csr, CsrStream};
use crate::util::Rng;

/// Generate the full sparse network: topology per the config's radices,
/// weights per [`RadixNetConfig::weights`], every bias set to
/// [`RadixNetConfig::bias`].
pub fn generate(cfg: &RadixNetConfig) -> SparseNet {
    let layers = generate_layers(cfg, true);
    let biases: Vec<Vec<f32>> = layers.iter().map(|w| vec![cfg.bias; w.nrows]).collect();
    SparseNet::new(layers, cfg.activation).with_biases(biases)
}

/// Generate only the layer sparsity patterns (all values 1.0, no weight
/// draws) — cheaper when the caller needs structure only (partitioning
/// experiments at large N).
pub fn generate_structure(cfg: &RadixNetConfig) -> Vec<Csr> {
    generate_layers(cfg, false)
}

fn generate_layers(cfg: &RadixNetConfig, with_weights: bool) -> Vec<Csr> {
    let n = cfg.neurons();
    let d = cfg.radices.len();
    let st = strides(&cfg.radices);
    let mut rng = Rng::new(cfg.seed);
    let mut row: Vec<(u32, f32)> = Vec::new();
    (0..cfg.layers)
        .map(|k| {
            let stage = k % d;
            let (r, stride) = (cfg.radices[stage], st[stage]);
            let perm = cfg.permute.then(|| rng.permutation(n));
            let mut stream = CsrStream::with_nnz_capacity(n, n, n * r);
            for j in 0..n {
                let base = stage_row_base(r, stride, j);
                row.clear();
                for t in 0..r {
                    let i = base + t * stride;
                    let c = perm.as_ref().map_or(i as u32, |p| p[i]);
                    let w = if with_weights {
                        cfg.weights.draw(&mut rng)
                    } else {
                        1.0
                    };
                    row.push((c, w));
                }
                stream.push_row_unsorted(&mut row).expect("radixnet row");
            }
            stream.finish()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::WeightScheme;
    use super::*;
    use crate::dnn::Activation;

    #[test]
    fn regular_degree_per_layer() {
        let cfg = RadixNetConfig {
            radices: vec![4, 8],
            layers: 4,
            seed: 1,
            ..RadixNetConfig::default()
        };
        let net = generate(&cfg);
        assert_eq!(net.depth(), 4);
        // stage 0 layers have degree 4, stage 1 layers degree 8
        for (k, w) in net.layers.iter().enumerate() {
            let expect = if k % 2 == 0 { 4 } else { 8 };
            for r in 0..w.nrows {
                assert_eq!(w.row_nnz(r), expect, "layer {k} row {r}");
            }
        }
        assert!(net.validate().is_ok());
    }

    #[test]
    fn full_connectivity_after_all_stages() {
        // After d consecutive stages every input reaches every output:
        // the product of the stage patterns is dense.
        let cfg = RadixNetConfig {
            radices: vec![3, 4],
            layers: 2,
            seed: 2,
            activation: Activation::Identity,
            ..RadixNetConfig::default()
        };
        let pats = generate_structure(&cfg);
        let n = cfg.neurons();
        // reach[j] = set of inputs reaching neuron j after both layers
        let mut reach: Vec<std::collections::HashSet<u32>> =
            (0..n).map(|i| [i as u32].into_iter().collect()).collect();
        for w in &pats {
            let mut next = vec![std::collections::HashSet::new(); n];
            for j in 0..n {
                let (cols, _) = w.row(j);
                for &c in cols {
                    let src = reach[c as usize].clone();
                    next[j].extend(src);
                }
            }
            reach = next;
        }
        for j in 0..n {
            assert_eq!(reach[j].len(), n, "output {j} not fully connected");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = RadixNetConfig::graph_challenge(64, 6).unwrap();
        let a = generate(&cfg);
        let b = generate(&cfg);
        for (wa, wb) in a.layers.iter().zip(b.layers.iter()) {
            assert_eq!(wa, wb);
        }
    }

    #[test]
    fn weights_in_unit_interval() {
        let cfg = RadixNetConfig::graph_challenge(256, 3).unwrap();
        let net = generate(&cfg);
        for w in &net.layers {
            assert!(w.vals.iter().all(|&v| (-1.0..1.0).contains(&v)));
        }
    }

    #[test]
    fn constant_weight_scheme_and_bias_applied() {
        let cfg = RadixNetConfig {
            radices: vec![4, 4],
            layers: 3,
            seed: 9,
            weights: WeightScheme::Constant(0.25),
            bias: -0.125,
            activation: Activation::ReluClip,
            ..RadixNetConfig::default()
        };
        let net = generate(&cfg);
        for w in &net.layers {
            assert!(w.vals.iter().all(|&v| v == 0.25));
        }
        for b in &net.biases {
            assert!(b.iter().all(|&v| v == -0.125));
        }
    }

    #[test]
    fn permutation_preserves_degree_and_changes_pattern() {
        let base = RadixNetConfig {
            radices: vec![8, 8],
            layers: 2,
            seed: 3,
            ..RadixNetConfig::default()
        };
        let mut permuted = base.clone();
        permuted.permute = true;
        let a = generate_structure(&base);
        let b = generate_structure(&permuted);
        assert_ne!(a[0].indices, b[0].indices);
        for r in 0..64 {
            assert_eq!(b[0].row_nnz(r), 8);
        }
    }

    #[test]
    fn structure_matches_generate() {
        let cfg = RadixNetConfig::graph_challenge(64, 5).unwrap();
        let net = generate(&cfg);
        let pats = generate_structure(&cfg);
        for (w, p) in net.layers.iter().zip(pats.iter()) {
            assert_eq!(w.indptr, p.indptr);
            assert_eq!(w.indices, p.indices);
        }
    }
}
