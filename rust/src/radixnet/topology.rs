//! Mixed-radix butterfly topology underlying RadiX-Net.
//!
//! Given radices `[r_0 … r_{d-1}]` with `N = Π r_s`, a neuron index is a
//! mixed-radix number; the layer at depth `k` applies butterfly stage
//! `s = k mod d`, connecting output neuron `j` to the `r_s` input neurons
//! that agree with `j` on every digit except digit `s`. Row (and column)
//! degree of that layer is therefore exactly `r_s`, and every input
//! reaches every output after `d` consecutive stages.

/// Digit strides for the mixed-radix representation (little-endian: digit
/// 0 is the least significant).
pub fn strides(radices: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; radices.len()];
    for i in 1..radices.len() {
        s[i] = s[i - 1] * radices[i - 1];
    }
    s
}

/// Row degree (= column degree) of the layer at depth `k` — the radix of
/// the butterfly stage that layer applies.
pub fn stage_degree(radices: &[usize], k: usize) -> usize {
    radices[k % radices.len()]
}

/// Base index of butterfly row `j` under a stage with radix `r` and digit
/// stride `stride`: `j` with digit `s` zeroed. Row `j`'s neighbors are
/// `base + t·stride` for `t in 0..r`, in ascending index order.
#[inline]
pub fn stage_row_base(r: usize, stride: usize, j: usize) -> usize {
    j - ((j / stride) % r) * stride
}

/// Full `(row, col)` pattern of butterfly stage `stage`, in row-major
/// emission order. Kept for structure-only consumers and tests; the
/// generator streams row-by-row via [`stage_row_base`] instead of
/// materializing the pair list.
pub fn stage_pattern(radices: &[usize], stage: usize) -> Vec<(u32, u32)> {
    let n: usize = radices.iter().product();
    let st = strides(radices);
    let r = radices[stage];
    let stride = st[stage];
    let mut pairs = Vec::with_capacity(n * r);
    for j in 0..n {
        let base = stage_row_base(r, stride, j);
        for t in 0..r {
            let i = base + t * stride;
            pairs.push((j as u32, i as u32));
        }
    }
    pairs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_are_digit_place_values() {
        assert_eq!(strides(&[4, 8, 2]), vec![1, 4, 32]);
        assert_eq!(strides(&[32, 32]), vec![1, 32]);
    }

    #[test]
    fn stage_degree_cycles_through_radices() {
        let radices = [4usize, 8, 2];
        for k in 0..9 {
            assert_eq!(stage_degree(&radices, k), radices[k % 3]);
        }
    }

    #[test]
    fn stage_pattern_rows_match_base_and_stride() {
        let radices = [3usize, 4];
        for stage in 0..2 {
            let pairs = stage_pattern(&radices, stage);
            let st = strides(&radices);
            let (r, stride) = (radices[stage], st[stage]);
            assert_eq!(pairs.len(), 12 * r);
            for (j, i) in pairs {
                let base = stage_row_base(r, stride, j as usize);
                let t = (i as usize - base) / stride;
                assert!(t < r);
                assert_eq!(base + t * stride, i as usize);
            }
        }
    }
}
