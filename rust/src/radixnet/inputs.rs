//! Graph-Challenge-style batched inputs and category extraction.
//!
//! The challenge feeds tens of thousands of sparse binary feature rows
//! (60 000 in the published runs) through the network and scores which
//! inputs still have active neurons at the output — the "categories".
//! Inputs here are synthetic but deterministic: every column of a batch
//! draws its own fill density, so some inputs die inside the network and
//! some survive the row-sum threshold. A single shared density would make
//! categories all-or-nothing and the cross-engine category check vacuous.

use crate::util::Rng;

/// Deterministic sparse 0/1 feature batch in the crate's row-major
/// activation layout: `[neurons × batch]`, column `c` holding input `c`.
/// Each column's fill density is drawn uniformly from `[0.05, 0.5)`.
pub fn gc_input_batch(neurons: usize, batch: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0x6C19_0956_31);
    let mut x = vec![0f32; neurons * batch];
    for c in 0..batch {
        let density = 0.05 + 0.45 * rng.gen_f64();
        for r in 0..neurons {
            if rng.gen_bool(density) {
                x[r * batch + c] = 1.0;
            }
        }
    }
    x
}

/// Graph Challenge categories: the input columns whose final-layer
/// activation sum exceeds `threshold`. The spec counts inputs with *any*
/// nonzero output, which threshold `0.0` reproduces for the ReLU-family
/// activations (all outputs nonnegative, so the sum is positive exactly
/// when some neuron fired — summation order cannot flip that).
///
/// `out` is the row-major `[out_dim × batch]` final-layer activation
/// block, as returned by the inference drivers.
pub fn categories(out: &[f32], out_dim: usize, batch: usize, threshold: f32) -> Vec<u32> {
    assert_eq!(out.len(), out_dim * batch, "output block shape mismatch");
    let mut sums = vec![0f64; batch];
    for r in 0..out_dim {
        let row = &out[r * batch..(r + 1) * batch];
        for (c, &v) in row.iter().enumerate() {
            sums[c] += v as f64;
        }
    }
    (0..batch)
        .filter(|&c| sums[c] > threshold as f64)
        .map(|c| c as u32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_batch_is_deterministic_and_binary() {
        let a = gc_input_batch(64, 16, 7);
        let b = gc_input_batch(64, 16, 7);
        assert_eq!(a, b);
        assert!(a.iter().all(|&v| v == 0.0 || v == 1.0));
        let ones = a.iter().filter(|&&v| v == 1.0).count();
        assert!(ones > 0 && ones < a.len());
    }

    #[test]
    fn column_densities_vary() {
        let x = gc_input_batch(256, 8, 3);
        let col_count = |c: usize| (0..256).filter(|&r| x[r * 8 + c] == 1.0).count();
        let counts: Vec<usize> = (0..8).map(col_count).collect();
        assert_ne!(counts.iter().min(), counts.iter().max());
    }

    #[test]
    fn categories_threshold_on_column_sums() {
        // out_dim 2, batch 3: column sums are 1.0, 0.0, 3.0
        let out = vec![1.0, 0.0, 2.0, 0.0, 0.0, 1.0];
        assert_eq!(categories(&out, 2, 3, 0.0), vec![0, 2]);
        assert_eq!(categories(&out, 2, 3, 2.5), vec![2]);
        assert!(categories(&out, 2, 3, 10.0).is_empty());
    }
}
