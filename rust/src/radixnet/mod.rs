//! RadiX-Net synthetic sparse DNN generator — the Graph Challenge
//! workload subsystem.
//!
//! Reimplementation of the generator behind the Sparse Deep Neural Network
//! Graph Challenge benchmark (Kepner & Robinett, "RadiX-Net: Structured
//! Sparse Matrices for Deep Neural Networks", IPDPSW'19), which the paper
//! uses for all experiments (Section 6.1).
//!
//! Topology: given mixed radices `[r_0 … r_{d-1}]` with `N = Π r_s`, a
//! neuron index is a mixed-radix number. The layer at depth `k` applies
//! butterfly stage `s = k mod d`: neuron `j` of layer k+1 connects to every
//! neuron `i` of layer k that agrees with `j` on all digits except digit
//! `s`. Row degree of layer k is therefore `r_{k mod d}`, every
//! input-output pair is connected after `d` consecutive layers, and the
//! structure is exactly the Kronecker/butterfly family RadiX-Net draws
//! from. Optional seeded inter-layer permutations break alignment (off for
//! the benchmark configs, available for robustness tests).
//!
//! Module layout:
//! - [`topology`] — the pure butterfly math (strides, per-row neighbor
//!   bases, stage degrees).
//! - [`generator`] — streamed layer construction through
//!   [`crate::sparse::CsrStream`]: rows go straight into the final CSR
//!   arrays, no COO intermediate, exact capacity reserved up front.
//! - [`inputs`] — Graph-Challenge-style sparse input batches and the
//!   row-sum-threshold category extraction.

pub mod generator;
pub mod inputs;
pub mod topology;

pub use generator::{generate, generate_structure};
pub use inputs::{categories, gc_input_batch};
pub use topology::stage_degree;

use crate::dnn::Activation;
use crate::util::Rng;

/// How the generator fills layer weights.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WeightScheme {
    /// Seeded uniform weights in `[lo, hi)` — the paper's training setup
    /// (§6.1 draws U\[-1, 1\]).
    Uniform {
        /// Inclusive lower bound of the draw.
        lo: f32,
        /// Exclusive upper bound of the draw.
        hi: f32,
    },
    /// Every weight set to the same constant — the Graph Challenge
    /// inference spec (1/16 for the published networks).
    Constant(f32),
}

impl Default for WeightScheme {
    fn default() -> Self {
        WeightScheme::Uniform { lo: -1.0, hi: 1.0 }
    }
}

impl WeightScheme {
    /// Draw one weight (advances the RNG only for randomized schemes, so
    /// constant-weight networks stay bit-compatible across scheme sets).
    pub fn draw(&self, rng: &mut Rng) -> f32 {
        match *self {
            WeightScheme::Uniform { lo, hi } => rng.gen_f32_range(lo, hi),
            WeightScheme::Constant(w) => w,
        }
    }
}

/// The published Graph Challenge bias for an `N`-neuron-per-layer network
/// (−0.30, −0.35, −0.40, −0.45 for N = 1024, 4096, 16384, 65536),
/// extended to the CI-scale sizes by the same −0.05-per-4× step.
pub fn gc_bias(neurons: usize) -> f32 {
    match neurons {
        1024 => -0.30,
        4096 => -0.35,
        16384 => -0.40,
        65536 => -0.45,
        _ => {
            let steps = ((neurons as f64 / 1024.0).ln() / 4f64.ln()).round();
            (-0.30 - 0.05 * steps) as f32
        }
    }
}

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct RadixNetConfig {
    /// Mixed radices; the neuron count per layer is their product.
    pub radices: Vec<usize>,
    /// Number of weight layers L.
    pub layers: usize,
    /// RNG seed for weights (and permutations if enabled).
    pub seed: u64,
    /// Apply a random inter-layer permutation per layer.
    pub permute: bool,
    /// Activation applied after every layer's bias shift.
    pub activation: Activation,
    /// Weight fill scheme (uniform draws by default).
    pub weights: WeightScheme,
    /// Constant bias applied to every neuron of every layer.
    pub bias: f32,
}

impl Default for RadixNetConfig {
    /// Empty-topology placeholder, mainly for `..Default::default()`
    /// struct spreads in tests; set `radices`/`layers` before generating.
    fn default() -> Self {
        Self {
            radices: Vec::new(),
            layers: 0,
            seed: 0x5EED,
            permute: false,
            activation: Activation::Sigmoid,
            weights: WeightScheme::default(),
            bias: 0.0,
        }
    }
}

impl RadixNetConfig {
    /// Benchmark presets matching the paper's four network sizes
    /// (N = 1024, 4096, 16384, 65536 neurons/layer): uniform U\[-1, 1\]
    /// weights, zero bias, sigmoid — the training setup of §6.1.
    pub fn graph_challenge(neurons: usize, layers: usize) -> Option<Self> {
        let radices: Vec<usize> = match neurons {
            1024 => vec![32, 32],
            4096 => vec![16, 16, 16],
            16384 => vec![32, 32, 16],
            65536 => vec![16, 16, 16, 16],
            // smaller sizes for CI-scale runs
            64 => vec![8, 8],
            256 => vec![16, 16],
            _ => return None,
        };
        Some(Self {
            radices,
            layers,
            ..Self::default()
        })
    }

    /// Graph Challenge **inference** preset (arXiv 1909.05631): the same
    /// butterfly topology as [`RadixNetConfig::graph_challenge`], but with
    /// the challenge's constant weights (`2 / r_min`, which is the
    /// published 1/16 at N = 1024), the published per-size bias
    /// ([`gc_bias`]), and ReLU clipped to \[0, 32\]
    /// ([`Activation::ReluClip`]).
    pub fn graph_challenge_inference(neurons: usize, layers: usize) -> Option<Self> {
        let mut cfg = Self::graph_challenge(neurons, layers)?;
        let r_min = cfg.radices.iter().copied().min().unwrap_or(1);
        cfg.weights = WeightScheme::Constant(2.0 / r_min as f32);
        cfg.bias = gc_bias(neurons);
        cfg.activation = Activation::ReluClip;
        Some(cfg)
    }

    /// Neurons per layer (the product of the radices).
    pub fn neurons(&self) -> usize {
        self.radices.iter().product()
    }

    /// Total edge (nonzero weight) count of the generated network:
    /// `Σ_k N · r_{k mod d}`.
    pub fn total_edges(&self) -> u64 {
        let n = self.neurons() as u64;
        (0..self.layers)
            .map(|k| n * stage_degree(&self.radices, k) as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neuron_count_is_radix_product() {
        let cfg = RadixNetConfig::graph_challenge(1024, 4).unwrap();
        assert_eq!(cfg.neurons(), 1024);
        assert_eq!(
            RadixNetConfig::graph_challenge(65536, 1).unwrap().neurons(),
            65536
        );
    }

    #[test]
    fn total_edges_counts_stage_degrees() {
        // N=1024, [32,32]: every layer has 1024·32 = 32768 edges, so the
        // 32-layer default CLI workload crosses the 1M-edge line exactly
        let cfg = RadixNetConfig::graph_challenge(1024, 32).unwrap();
        assert_eq!(cfg.total_edges(), 1_048_576);
        // mixed radices cycle: [32,32,16] → 32K, 32K, 16K, 32K, ...
        let cfg = RadixNetConfig::graph_challenge(16384, 4).unwrap();
        let n = 16384u64;
        assert_eq!(cfg.total_edges(), n * 32 + n * 32 + n * 16 + n * 32);
    }

    #[test]
    fn inference_preset_matches_published_spec() {
        let cfg = RadixNetConfig::graph_challenge_inference(1024, 120).unwrap();
        assert_eq!(cfg.weights, WeightScheme::Constant(1.0 / 16.0));
        assert_eq!(cfg.bias, -0.30);
        assert_eq!(cfg.activation, Activation::ReluClip);
        assert_eq!(
            RadixNetConfig::graph_challenge_inference(4096, 1)
                .unwrap()
                .bias,
            -0.35
        );
        assert_eq!(
            RadixNetConfig::graph_challenge_inference(65536, 1)
                .unwrap()
                .bias,
            -0.45
        );
    }

    #[test]
    fn gc_bias_extends_published_step_to_ci_sizes() {
        assert_eq!(gc_bias(16384), -0.40);
        assert!((gc_bias(256) - -0.25).abs() < 1e-6);
        assert!((gc_bias(64) - -0.20).abs() < 1e-6);
    }
}
