//! RadiX-Net synthetic sparse DNN generator.
//!
//! Reimplementation of the generator behind the Sparse Deep Neural Network
//! Graph Challenge benchmark (Kepner & Robinett, "RadiX-Net: Structured
//! Sparse Matrices for Deep Neural Networks", IPDPSW'19), which the paper
//! uses for all experiments (Section 6.1).
//!
//! Topology: given mixed radices `[r_0 … r_{d-1}]` with `N = Π r_s`, a
//! neuron index is a mixed-radix number. The layer at depth `k` applies
//! butterfly stage `s = k mod d`: neuron `j` of layer k+1 connects to every
//! neuron `i` of layer k that agrees with `j` on all digits except digit
//! `s`. Row degree of layer k is therefore `r_{k mod d}`, every
//! input-output pair is connected after `d` consecutive layers, and the
//! structure is exactly the Kronecker/butterfly family RadiX-Net draws
//! from. Optional seeded inter-layer permutations break alignment (off for
//! the benchmark configs, available for robustness tests).

use crate::dnn::{Activation, SparseNet};
use crate::sparse::{Coo, Csr};
use crate::util::Rng;

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct RadixNetConfig {
    /// Mixed radices; the neuron count per layer is their product.
    pub radices: Vec<usize>,
    /// Number of weight layers L.
    pub layers: usize,
    /// RNG seed for weights (and permutations if enabled).
    pub seed: u64,
    /// Apply a random inter-layer permutation per layer.
    pub permute: bool,
    pub activation: Activation,
}

impl RadixNetConfig {
    /// Benchmark presets matching the paper's four network sizes
    /// (N = 1024, 4096, 16384, 65536 neurons/layer).
    pub fn graph_challenge(neurons: usize, layers: usize) -> Option<Self> {
        let radices: Vec<usize> = match neurons {
            1024 => vec![32, 32],
            4096 => vec![16, 16, 16],
            16384 => vec![32, 32, 16],
            65536 => vec![16, 16, 16, 16],
            // smaller sizes for CI-scale runs
            64 => vec![8, 8],
            256 => vec![16, 16],
            _ => return None,
        };
        Some(Self {
            radices,
            layers,
            seed: 0x5EED,
            permute: false,
            activation: Activation::Sigmoid,
        })
    }

    pub fn neurons(&self) -> usize {
        self.radices.iter().product()
    }
}

/// Digit strides for the mixed-radix representation (little-endian: digit 0
/// is the least significant).
fn strides(radices: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; radices.len()];
    for i in 1..radices.len() {
        s[i] = s[i - 1] * radices[i - 1];
    }
    s
}

/// Build the sparse connectivity matrix for butterfly stage `stage`
/// (structure only; values filled by the caller).
fn stage_pattern(radices: &[usize], stage: usize) -> Vec<(u32, u32)> {
    let n: usize = radices.iter().product();
    let st = strides(radices);
    let r = radices[stage];
    let stride = st[stage];
    let mut pairs = Vec::with_capacity(n * r);
    for j in 0..n {
        let digit = (j / stride) % r;
        let base = j - digit * stride;
        for t in 0..r {
            let i = base + t * stride;
            pairs.push((j as u32, i as u32));
        }
    }
    pairs
}

/// Generate the full sparse network: weights U[-1,1] (paper §6.1), zero
/// biases, sigmoid activation by default.
pub fn generate(cfg: &RadixNetConfig) -> SparseNet {
    let n = cfg.neurons();
    let d = cfg.radices.len();
    let mut rng = Rng::new(cfg.seed);
    let mut layers: Vec<Csr> = Vec::with_capacity(cfg.layers);
    for k in 0..cfg.layers {
        let stage = k % d;
        let mut pairs = stage_pattern(&cfg.radices, stage);
        if cfg.permute {
            let perm = rng.permutation(n);
            for (_, i) in pairs.iter_mut() {
                *i = perm[*i as usize];
            }
        }
        let mut coo = Coo::with_capacity(n, n, pairs.len());
        for (j, i) in pairs {
            coo.push(j as usize, i as usize, rng.gen_f32_range(-1.0, 1.0));
        }
        layers.push(coo.to_csr());
    }
    SparseNet::new(layers, cfg.activation)
}

/// Generate only the layer sparsity patterns (no weights) — cheaper when the
/// caller needs structure only (partitioning experiments at large N).
pub fn generate_structure(cfg: &RadixNetConfig) -> Vec<Csr> {
    let n = cfg.neurons();
    let d = cfg.radices.len();
    let mut rng = Rng::new(cfg.seed);
    (0..cfg.layers)
        .map(|k| {
            let mut pairs = stage_pattern(&cfg.radices, k % d);
            if cfg.permute {
                let perm = rng.permutation(n);
                for (_, i) in pairs.iter_mut() {
                    *i = perm[*i as usize];
                }
            }
            let mut coo = Coo::with_capacity(n, n, pairs.len());
            for (j, i) in pairs {
                coo.push(j as usize, i as usize, 1.0);
            }
            coo.to_csr()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neuron_count_is_radix_product() {
        let cfg = RadixNetConfig::graph_challenge(1024, 4).unwrap();
        assert_eq!(cfg.neurons(), 1024);
        assert_eq!(
            RadixNetConfig::graph_challenge(65536, 1).unwrap().neurons(),
            65536
        );
    }

    #[test]
    fn regular_degree_per_layer() {
        let cfg = RadixNetConfig {
            radices: vec![4, 8],
            layers: 4,
            seed: 1,
            permute: false,
            activation: Activation::Sigmoid,
        };
        let net = generate(&cfg);
        assert_eq!(net.depth(), 4);
        // stage 0 layers have degree 4, stage 1 layers degree 8
        for (k, w) in net.layers.iter().enumerate() {
            let expect = if k % 2 == 0 { 4 } else { 8 };
            for r in 0..w.nrows {
                assert_eq!(w.row_nnz(r), expect, "layer {k} row {r}");
            }
        }
        assert!(net.validate().is_ok());
    }

    #[test]
    fn full_connectivity_after_all_stages() {
        // After d consecutive stages every input reaches every output:
        // the product of the stage patterns is dense.
        let cfg = RadixNetConfig {
            radices: vec![3, 4],
            layers: 2,
            seed: 2,
            permute: false,
            activation: Activation::Identity,
        };
        let pats = generate_structure(&cfg);
        let n = cfg.neurons();
        // reach[j] = set of inputs reaching neuron j after both layers
        let mut reach: Vec<std::collections::HashSet<u32>> =
            (0..n).map(|i| [i as u32].into_iter().collect()).collect();
        for w in &pats {
            let mut next = vec![std::collections::HashSet::new(); n];
            for j in 0..n {
                let (cols, _) = w.row(j);
                for &c in cols {
                    let src = reach[c as usize].clone();
                    next[j].extend(src);
                }
            }
            reach = next;
        }
        for j in 0..n {
            assert_eq!(reach[j].len(), n, "output {j} not fully connected");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = RadixNetConfig::graph_challenge(64, 6).unwrap();
        let a = generate(&cfg);
        let b = generate(&cfg);
        for (wa, wb) in a.layers.iter().zip(b.layers.iter()) {
            assert_eq!(wa, wb);
        }
    }

    #[test]
    fn weights_in_unit_interval() {
        let cfg = RadixNetConfig::graph_challenge(256, 3).unwrap();
        let net = generate(&cfg);
        for w in &net.layers {
            assert!(w.vals.iter().all(|&v| (-1.0..1.0).contains(&v)));
        }
    }

    #[test]
    fn permutation_preserves_degree_and_changes_pattern() {
        let base = RadixNetConfig {
            radices: vec![8, 8],
            layers: 2,
            seed: 3,
            permute: false,
            activation: Activation::Sigmoid,
        };
        let mut permuted = base.clone();
        permuted.permute = true;
        let a = generate_structure(&base);
        let b = generate_structure(&permuted);
        assert_ne!(a[0].indices, b[0].indices);
        for r in 0..64 {
            assert_eq!(b[0].row_nnz(r), 8);
        }
    }

    #[test]
    fn structure_matches_generate() {
        let cfg = RadixNetConfig::graph_challenge(64, 5).unwrap();
        let net = generate(&cfg);
        let pats = generate_structure(&cfg);
        for (w, p) in net.layers.iter().zip(pats.iter()) {
            assert_eq!(w.indptr, p.indptr);
            assert_eq!(w.indices, p.indices);
        }
    }
}
