//! Unified metrics registry: one snapshotable interface over the
//! counters that previously lived scattered across the crate — fabric
//! endpoint byte/message counters ([`crate::comm::FabricStats`]),
//! per-rank [`crate::util::PhaseTimer`] phase sums, and the serving
//! pool's [`crate::serving::StatsSnapshot`] — rendered as
//! Prometheus-style text exposition.

use crate::comm::FabricStats;
use crate::serving::StatsSnapshot;
use crate::util::PhaseTimer;

/// One metric family: a name, help line, kind (`counter`/`gauge`), and
/// samples keyed by their rendered label set.
#[derive(Debug)]
struct Family {
    name: String,
    help: &'static str,
    kind: &'static str,
    samples: Vec<(String, f64)>,
}

/// Collects metric samples from the crate's subsystems and renders them
/// in the Prometheus text exposition format. Build one, feed it the
/// snapshots you have (phases, fabric stats, serving stats, ad-hoc
/// counters), then call [`MetricsRegistry::render`].
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    families: Vec<Family>,
}

/// Render a label set as `{k="v",...}`, or the empty string for no
/// labels.
fn label_str(labels: &[(&str, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let body: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{v}\""))
        .collect();
    format!("{{{}}}", body.join(","))
}

impl MetricsRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    fn push(
        &mut self,
        kind: &'static str,
        name: &str,
        help: &'static str,
        labels: &[(&str, String)],
        value: f64,
    ) {
        let sample = (label_str(labels), value);
        if let Some(f) = self.families.iter_mut().find(|f| f.name == name) {
            f.samples.push(sample);
            return;
        }
        self.families.push(Family {
            name: name.to_string(),
            help,
            kind,
            samples: vec![sample],
        });
    }

    /// Add one counter sample (monotonic total).
    pub fn counter(&mut self, name: &str, help: &'static str, labels: &[(&str, String)], v: f64) {
        self.push("counter", name, help, labels, v);
    }

    /// Add one gauge sample (point-in-time value).
    pub fn gauge(&mut self, name: &str, help: &'static str, labels: &[(&str, String)], v: f64) {
        self.push("gauge", name, help, labels, v);
    }

    /// Record every phase sum of one rank's [`PhaseTimer`] as
    /// `spdnn_phase_seconds_total{rank,phase}`.
    pub fn record_phases(&mut self, rank: u32, timer: &PhaseTimer) {
        for (phase, d) in timer.phases() {
            self.counter(
                "spdnn_phase_seconds_total",
                "Seconds spent per engine phase (spmv/updt/comm/wait), per rank.",
                &[("rank", rank.to_string()), ("phase", phase.to_string())],
                d.as_secs_f64(),
            );
        }
    }

    /// Record one rank endpoint's aggregate and per-peer traffic
    /// counters. Peer rows with no traffic are skipped to keep the
    /// exposition proportional to the communication pattern, not the
    /// fabric size.
    pub fn record_fabric(&mut self, rank: u32, st: &FabricStats) {
        let r = [("rank", rank.to_string())];
        self.counter(
            "spdnn_fabric_sent_words_total",
            "Words sent as they traveled the wire (encoded words for lossy codecs).",
            &r,
            st.sent_words as f64,
        );
        self.counter(
            "spdnn_fabric_raw_bytes_total",
            "Pre-encoding payload bytes of every send.",
            &r,
            st.sent_raw_bytes as f64,
        );
        for (dir, msgs, bytes) in [
            ("send", st.sent_msgs, st.sent_wire_bytes),
            ("recv", st.recv_msgs, st.recv_wire_bytes),
        ] {
            let rd = [("rank", rank.to_string()), ("dir", dir.to_string())];
            self.counter(
                "spdnn_fabric_msgs_total",
                "Messages sent / received-and-consumed per rank endpoint.",
                &rd,
                msgs as f64,
            );
            self.counter(
                "spdnn_fabric_wire_bytes_total",
                "Bytes on the wire (post-codec) per rank endpoint and direction.",
                &rd,
                bytes as f64,
            );
        }
        for (peer, pc) in st.peers.iter().enumerate() {
            for (dir, msgs, bytes) in [
                ("send", pc.sent_msgs, pc.sent_bytes),
                ("recv", pc.recv_msgs, pc.recv_bytes),
            ] {
                if msgs == 0 && bytes == 0 {
                    continue;
                }
                let l = [
                    ("rank", rank.to_string()),
                    ("peer", peer.to_string()),
                    ("dir", dir.to_string()),
                ];
                self.counter(
                    "spdnn_fabric_peer_msgs_total",
                    "Messages per (rank, peer, direction).",
                    &l,
                    msgs as f64,
                );
                self.counter(
                    "spdnn_fabric_peer_bytes_total",
                    "Wire bytes per (rank, peer, direction).",
                    &l,
                    bytes as f64,
                );
            }
        }
    }

    /// Record one replica-group training thread's fabric counters,
    /// labelled by group, rank, and which of its two fabrics they came
    /// from (`intra` = the model-parallel engine traffic inside the
    /// group, `inter` = the cross-group gradient all-reduce ring). The
    /// replica drivers feed every `[group][rank]` cell of both counter
    /// grids through here, so the intra/inter split — the whole point of
    /// compressing the gradient exchange — is scrapeable directly.
    pub fn record_replica_fabric(
        &mut self,
        group: usize,
        rank: u32,
        fabric: &'static str,
        st: &FabricStats,
    ) {
        let l = [
            ("group", group.to_string()),
            ("rank", rank.to_string()),
            ("fabric", fabric.to_string()),
        ];
        self.counter(
            "spdnn_replica_sent_words_total",
            "Wire words sent per replica-group thread, split by intra/inter fabric.",
            &l,
            st.sent_words as f64,
        );
        for (dir, msgs, bytes) in [
            ("send", st.sent_msgs, st.sent_wire_bytes),
            ("recv", st.recv_msgs, st.recv_wire_bytes),
        ] {
            let ld = [
                ("group", group.to_string()),
                ("rank", rank.to_string()),
                ("fabric", fabric.to_string()),
                ("dir", dir.to_string()),
            ];
            self.counter(
                "spdnn_replica_msgs_total",
                "Messages per replica-group thread, fabric, and direction.",
                &ld,
                msgs as f64,
            );
            self.counter(
                "spdnn_replica_wire_bytes_total",
                "Post-codec wire bytes per replica-group thread, fabric, and direction.",
                &ld,
                bytes as f64,
            );
        }
    }

    /// Record a serving-pool snapshot: request/batch/shed/rebuild
    /// counters, byte totals, the recovery counters (retries, respawns,
    /// watchdog trips, checksum failures, breaker state), and the latency
    /// distribution (bucketed quantiles plus the exact min/max and
    /// overflow count the histogram now tracks).
    pub fn record_serving(&mut self, s: &StatsSnapshot) {
        let no: [(&str, String); 0] = [];
        for (name, help, v) in [
            (
                "spdnn_pool_requests_total",
                "Requests answered successfully.",
                s.requests as f64,
            ),
            (
                "spdnn_pool_failed_requests_total",
                "Requests failed by a rank failure.",
                s.failed_requests as f64,
            ),
            (
                "spdnn_pool_shed_requests_total",
                "Requests shed for blowing their queue-wait SLO.",
                s.shed_requests as f64,
            ),
            (
                "spdnn_pool_batches_total",
                "Fused batches dispatched.",
                s.batches as f64,
            ),
            (
                "spdnn_pool_rebuilds_total",
                "Generation rebuilds forced by rank failures.",
                s.pool_rebuilds as f64,
            ),
            (
                "spdnn_pool_columns_total",
                "SpMM columns served.",
                s.columns as f64,
            ),
            (
                "spdnn_pool_raw_bytes_total",
                "Pre-encoding payload bytes moved between ranks.",
                s.raw_bytes as f64,
            ),
            (
                "spdnn_pool_wire_bytes_total",
                "Bytes actually shipped after the wire codec.",
                s.wire_bytes as f64,
            ),
            (
                "spdnn_pool_latency_overflow_total",
                "Latency samples above the histogram's last bucket.",
                s.overflow_latencies as f64,
            ),
            (
                "spdnn_pool_requests_retried_total",
                "Requests requeued onto a respawned generation after theirs failed.",
                s.requests_retried as f64,
            ),
            (
                "spdnn_pool_generations_respawned_total",
                "Generation respawns completed after failures.",
                s.generations_respawned as f64,
            ),
            (
                "spdnn_pool_watchdog_trips_total",
                "Generation failures rooted in a stall-watchdog trip.",
                s.watchdog_trips as f64,
            ),
            (
                "spdnn_pool_checksum_failures_total",
                "Generation failures rooted in a payload checksum mismatch.",
                s.checksum_failures as f64,
            ),
            (
                "spdnn_pool_unavailable_requests_total",
                "Requests fast-failed by an open circuit breaker.",
                s.unavailable_requests as f64,
            ),
        ] {
            self.counter(name, help, &no, v);
        }
        for (q, v) in [
            ("0.5", s.p50_secs),
            ("0.95", s.p95_secs),
            ("0.99", s.p99_secs),
        ] {
            self.gauge(
                "spdnn_pool_latency_seconds",
                "Request latency quantiles (bucketed, ±25 %).",
                &[("quantile", q.to_string())],
                v,
            );
        }
        for (name, help, v) in [
            (
                "spdnn_pool_latency_mean_seconds",
                "Mean request latency (exact).",
                s.mean_latency_secs,
            ),
            (
                "spdnn_pool_latency_min_seconds",
                "Exact smallest request latency observed.",
                s.min_latency_secs,
            ),
            (
                "spdnn_pool_latency_max_seconds",
                "Exact largest request latency observed.",
                s.max_latency_secs,
            ),
            (
                "spdnn_pool_edges_per_second",
                "Aggregate edges/s over wall-clock since pool start.",
                s.edges_per_sec,
            ),
            (
                "spdnn_pool_wall_seconds",
                "Wall-clock seconds since pool start.",
                s.wall_secs,
            ),
            (
                "spdnn_pool_breaker_state",
                "Circuit-breaker state: 0 closed, 1 half-open, 2 open.",
                s.breaker_state as f64,
            ),
        ] {
            self.gauge(name, help, &no, v);
        }
    }

    /// Prometheus text exposition: `# HELP` / `# TYPE` once per family,
    /// then one `name{labels} value` line per sample.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.families {
            out.push_str(&format!("# HELP {} {}\n", f.name, f.help));
            out.push_str(&format!("# TYPE {} {}\n", f.name, f.kind));
            for (labels, v) in &f.samples {
                out.push_str(&format!("{}{} {}\n", f.name, labels, v));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn render_groups_families_and_labels() {
        let mut reg = MetricsRegistry::new();
        let mut t = PhaseTimer::new();
        t.add("spmv", Duration::from_millis(250));
        t.add("wait", Duration::from_millis(750));
        reg.record_phases(0, &t);
        reg.record_phases(1, &t);
        let text = reg.render();
        // HELP/TYPE exactly once per family, one line per sample
        assert_eq!(text.matches("# HELP spdnn_phase_seconds_total").count(), 1);
        assert_eq!(text.matches("# TYPE spdnn_phase_seconds_total counter").count(), 1);
        assert!(text.contains("spdnn_phase_seconds_total{rank=\"0\",phase=\"spmv\"} 0.25"));
        assert!(text.contains("spdnn_phase_seconds_total{rank=\"1\",phase=\"wait\"} 0.75"));
    }

    #[test]
    fn replica_fabric_rows_carry_group_and_fabric_labels() {
        let st = FabricStats {
            sent_words: 64,
            sent_msgs: 3,
            sent_raw_bytes: 512,
            sent_wire_bytes: 256,
            recv_msgs: 3,
            recv_wire_bytes: 256,
            peers: Vec::new(),
        };
        let mut reg = MetricsRegistry::new();
        reg.record_replica_fabric(1, 0, "inter", &st);
        let text = reg.render();
        assert!(text.contains(
            "spdnn_replica_sent_words_total{group=\"1\",rank=\"0\",fabric=\"inter\"} 64"
        ));
        assert!(text.contains(
            "spdnn_replica_wire_bytes_total{group=\"1\",rank=\"0\",fabric=\"inter\",dir=\"send\"} 256"
        ));
    }

    #[test]
    fn fabric_stats_expose_per_peer_rows() {
        use crate::comm::fabric::PeerCounters;
        let st = FabricStats {
            sent_words: 10,
            sent_msgs: 2,
            sent_raw_bytes: 40,
            sent_wire_bytes: 40,
            recv_msgs: 1,
            recv_wire_bytes: 20,
            peers: vec![
                PeerCounters::default(),
                PeerCounters {
                    sent_msgs: 2,
                    sent_bytes: 40,
                    recv_msgs: 1,
                    recv_bytes: 20,
                },
            ],
        };
        let mut reg = MetricsRegistry::new();
        reg.record_fabric(0, &st);
        let text = reg.render();
        assert!(text.contains("spdnn_fabric_msgs_total{rank=\"0\",dir=\"send\"} 2"));
        assert!(text.contains("spdnn_fabric_wire_bytes_total{rank=\"0\",dir=\"recv\"} 20"));
        assert!(text
            .contains("spdnn_fabric_peer_bytes_total{rank=\"0\",peer=\"1\",dir=\"send\"} 40"));
        // the silent peer 0 produced no rows
        assert!(!text.contains("peer=\"0\""));
    }
}
