//! Flight-recorder trace spans: a per-rank, fixed-capacity ring buffer
//! of timed spans plus a Chrome trace-event JSON exporter.
//!
//! The recorder is built for the hot path: when tracing is off the
//! [`Tracer`] holds a zero-capacity buffer, [`Tracer::start`] returns
//! `None` without reading the clock, and [`Tracer::end`] early-returns
//! before touching memory — the instrumented engines pay two branch
//! instructions per span site. When tracing is on, each span records a
//! name, category, layer, chunk, payload bytes, and `Instant`-based
//! start/duration in nanoseconds relative to a shared epoch, so spans
//! from different ranks land on one timeline.

use std::time::Instant;

/// Default ring capacity (spans per rank) when `SPDNN_TRACE=1`.
pub const DEFAULT_TRACE_CAPACITY: usize = 65_536;

/// Sentinel for spans not associated with a layer.
pub const NO_LAYER: u32 = u32::MAX;

/// Sentinel for spans not associated with a chunk.
pub const NO_CHUNK: u32 = u32::MAX;

/// Whether (and how) a rank records trace spans. The `On` variant
/// carries the shared epoch `Instant` so that every rank built from the
/// same mode value measures span timestamps against one clock origin —
/// copy a single `TraceMode` to all ranks rather than constructing one
/// per rank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceMode {
    /// No recording; span sites cost two branches and never allocate.
    Off,
    /// Record into a ring of `capacity` spans, timestamped against `epoch`.
    On {
        /// Ring capacity in spans; the oldest span is overwritten on wrap.
        capacity: usize,
        /// Shared clock origin for `start_ns` timestamps.
        epoch: Instant,
    },
}

impl TraceMode {
    /// Tracing on with [`DEFAULT_TRACE_CAPACITY`] and a fresh epoch.
    pub fn on() -> Self {
        Self::with_capacity(DEFAULT_TRACE_CAPACITY)
    }

    /// Tracing on with an explicit ring capacity and a fresh epoch.
    pub fn with_capacity(capacity: usize) -> Self {
        TraceMode::On {
            capacity: capacity.max(1),
            epoch: Instant::now(),
        }
    }

    /// The process-wide mode from the `SPDNN_TRACE` environment variable,
    /// parsed once: unset/`0`/`off` → `Off`; `1`/`on` → default capacity;
    /// any other integer → that many spans per rank. All callers share
    /// one epoch, so env-driven ranks align on a single timeline.
    pub fn from_env() -> Self {
        use std::sync::OnceLock;
        static MODE: OnceLock<TraceMode> = OnceLock::new();
        *MODE.get_or_init(|| match std::env::var("SPDNN_TRACE").ok().as_deref() {
            None | Some("") | Some("0") | Some("off") => TraceMode::Off,
            Some("1") | Some("on") => TraceMode::on(),
            Some(s) => match s.parse::<usize>() {
                Ok(cap) => TraceMode::with_capacity(cap),
                Err(_) => TraceMode::Off,
            },
        })
    }

    /// True when this mode records spans.
    pub fn is_on(&self) -> bool {
        matches!(self, TraceMode::On { .. })
    }
}

/// One recorded interval. `start_ns`/`dur_ns` are nanoseconds relative
/// to the tracer's epoch; `layer`/`chunk` use [`NO_LAYER`]/[`NO_CHUNK`]
/// when not applicable; `bytes` is the raw payload size for send/post
/// spans and 0 elsewhere.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    /// Span name, e.g. `"spmv.boundary"` or `"wait"`.
    pub name: &'static str,
    /// Category: `"fwd"`, `"bwd"`, or `"pool"`.
    pub cat: &'static str,
    /// Layer index, or [`NO_LAYER`].
    pub layer: u32,
    /// Chunk index, or [`NO_CHUNK`].
    pub chunk: u32,
    /// Raw payload bytes moved inside the span (0 for compute spans).
    pub bytes: u64,
    /// Start offset from the epoch, nanoseconds.
    pub start_ns: u64,
    /// Duration, nanoseconds.
    pub dur_ns: u64,
}

/// Per-rank flight recorder: a fixed-capacity ring of [`Span`]s. Built
/// from a [`TraceMode`] at `RankState` construction; disabled tracers
/// never allocate and never read the clock.
#[derive(Debug)]
pub struct Tracer {
    enabled: bool,
    epoch: Instant,
    cap: usize,
    spans: Vec<Span>,
    head: usize,
    dropped: u64,
    rank: u32,
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::new(TraceMode::Off, 0)
    }
}

impl Tracer {
    /// A tracer for `rank` in the given mode. `Off` yields a recorder
    /// with a zero-capacity buffer that never allocates.
    pub fn new(mode: TraceMode, rank: u32) -> Self {
        match mode {
            TraceMode::Off => Tracer {
                enabled: false,
                epoch: Instant::now(),
                cap: 0,
                spans: Vec::new(),
                head: 0,
                dropped: 0,
                rank,
            },
            TraceMode::On { capacity, epoch } => Tracer {
                enabled: true,
                epoch,
                cap: capacity.max(1),
                spans: Vec::with_capacity(capacity.max(1)),
                head: 0,
                dropped: 0,
                rank,
            },
        }
    }

    /// True when this tracer records spans.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The rank this tracer was built for.
    pub fn rank(&self) -> u32 {
        self.rank
    }

    /// Spans overwritten after the ring wrapped.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Allocated ring capacity in spans (0 when disabled — the
    /// zero-allocation guarantee the tests pin down).
    pub fn buffer_capacity(&self) -> usize {
        self.spans.capacity()
    }

    /// Open a span: returns the start instant, or `None` (without
    /// reading the clock) when disabled. Pass the result to
    /// [`Tracer::end`].
    #[inline]
    pub fn start(&self) -> Option<Instant> {
        if self.enabled {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close a span opened by [`Tracer::start`] and record it. A `None`
    /// start (disabled tracer) is a no-op.
    #[inline]
    pub fn end(
        &mut self,
        t0: Option<Instant>,
        name: &'static str,
        cat: &'static str,
        layer: u32,
        chunk: u32,
        bytes: u64,
    ) {
        let Some(t0) = t0 else { return };
        let span = Span {
            name,
            cat,
            layer,
            chunk,
            bytes,
            start_ns: t0.duration_since(self.epoch).as_nanos() as u64,
            dur_ns: t0.elapsed().as_nanos() as u64,
        };
        if self.spans.len() < self.cap {
            self.spans.push(span);
        } else {
            self.spans[self.head] = span;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Chronological snapshot of the ring's current contents. When the
    /// ring has wrapped, the oldest surviving span comes first.
    pub fn spans(&self) -> Vec<Span> {
        let mut out = Vec::with_capacity(self.spans.len());
        out.extend_from_slice(&self.spans[self.head..]);
        out.extend_from_slice(&self.spans[..self.head]);
        out
    }
}

/// Render named span tracks as Chrome trace-event JSON (the format
/// `chrome://tracing` and Perfetto load): one process, one `tid` per
/// track, `"M"` thread-name metadata plus `"X"` complete events with
/// microsecond `ts`/`dur` and `layer`/`chunk`/`bytes` args (sentinel
/// values omitted).
pub fn chrome_trace_json(tracks: &[(String, Vec<Span>)]) -> String {
    let mut ev = Vec::new();
    for (tid, (name, _)) in tracks.iter().enumerate() {
        ev.push(format!(
            "{{\"ph\":\"M\",\"pid\":0,\"tid\":{tid},\"name\":\"thread_name\",\
             \"args\":{{\"name\":\"{name}\"}}}}"
        ));
    }
    for (tid, (_, spans)) in tracks.iter().enumerate() {
        for s in spans {
            let mut args = String::new();
            if s.layer != NO_LAYER {
                args.push_str(&format!("\"layer\":{},", s.layer));
            }
            if s.chunk != NO_CHUNK {
                args.push_str(&format!("\"chunk\":{},", s.chunk));
            }
            args.push_str(&format!("\"bytes\":{}", s.bytes));
            ev.push(format!(
                "{{\"ph\":\"X\",\"pid\":0,\"tid\":{tid},\"name\":\"{}\",\
                 \"cat\":\"{}\",\"ts\":{:.3},\"dur\":{:.3},\"args\":{{{args}}}}}",
                s.name,
                s.cat,
                s.start_ns as f64 / 1e3,
                s.dur_ns as f64 / 1e3,
            ));
        }
    }
    format!(
        "{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[{}]}}",
        ev.join(",")
    )
}

/// Fraction of the interval `[first span start, last span end]` covered
/// by the union of the given spans (0.0 for fewer than one span or a
/// zero-length window). Overlapping spans are merged first so nested
/// instrumentation does not double-count.
pub fn span_coverage(spans: &[Span]) -> f64 {
    if spans.is_empty() {
        return 0.0;
    }
    let mut iv: Vec<(u64, u64)> = spans
        .iter()
        .map(|s| (s.start_ns, s.start_ns + s.dur_ns))
        .collect();
    iv.sort_unstable();
    let lo = iv[0].0;
    let mut hi = 0u64;
    let mut covered = 0u64;
    let (mut cs, mut ce) = iv[0];
    for &(s, e) in &iv[1..] {
        if s <= ce {
            ce = ce.max(e);
        } else {
            covered += ce - cs;
            cs = s;
            ce = e;
        }
    }
    covered += ce - cs;
    for &(_, e) in &iv {
        hi = hi.max(e);
    }
    if hi <= lo {
        return 0.0;
    }
    covered as f64 / (hi - lo) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push(tr: &mut Tracer, i: u32) {
        let t0 = tr.start();
        tr.end(t0, "t", "fwd", i, NO_CHUNK, 0);
    }

    #[test]
    fn ring_wraps_and_counts_drops() {
        let mut tr = Tracer::new(TraceMode::with_capacity(4), 0);
        for i in 0..10 {
            push(&mut tr, i);
        }
        let spans = tr.spans();
        assert_eq!(spans.len(), 4);
        assert_eq!(tr.dropped(), 6);
        // Oldest surviving span first, strictly chronological.
        let layers: Vec<u32> = spans.iter().map(|s| s.layer).collect();
        assert_eq!(layers, vec![6, 7, 8, 9]);
        for w in spans.windows(2) {
            assert!(w[0].start_ns <= w[1].start_ns);
        }
    }

    #[test]
    fn disabled_tracer_never_allocates() {
        let mut tr = Tracer::new(TraceMode::Off, 3);
        assert!(!tr.enabled());
        for i in 0..1000 {
            let t0 = tr.start();
            assert!(t0.is_none());
            tr.end(t0, "t", "fwd", i, NO_CHUNK, 64);
        }
        assert_eq!(tr.buffer_capacity(), 0);
        assert!(tr.spans().is_empty());
        assert_eq!(tr.dropped(), 0);
    }

    #[test]
    fn shared_epoch_aligns_ranks() {
        let mode = TraceMode::with_capacity(8);
        let mut a = Tracer::new(mode, 0);
        let mut b = Tracer::new(mode, 1);
        push(&mut a, 0);
        push(&mut b, 0);
        let (sa, sb) = (a.spans()[0], b.spans()[0]);
        // Both measured against the same epoch: rank 1's span, opened
        // after rank 0's, cannot start earlier.
        assert!(sb.start_ns >= sa.start_ns);
    }

    #[test]
    fn chrome_json_shape() {
        let mut tr = Tracer::new(TraceMode::with_capacity(8), 0);
        let t0 = tr.start();
        tr.end(t0, "spmv.boundary", "fwd", 3, 1, 512);
        let json = chrome_trace_json(&[("rank 0".to_string(), tr.spans())]);
        assert!(json.contains("\"traceEvents\""));
        assert!(json.contains("\"ph\":\"M\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"name\":\"spmv.boundary\""));
        assert!(json.contains("\"layer\":3"));
        assert!(json.contains("\"chunk\":1"));
        assert!(json.contains("\"bytes\":512"));
    }

    #[test]
    fn coverage_merges_overlaps() {
        let s = |start: u64, dur: u64| Span {
            name: "t",
            cat: "fwd",
            layer: NO_LAYER,
            chunk: NO_CHUNK,
            bytes: 0,
            start_ns: start,
            dur_ns: dur,
        };
        assert_eq!(span_coverage(&[]), 0.0);
        // [0,10) and [5,15) overlap: union 15 over window 15 → 1.0.
        let full = span_coverage(&[s(0, 10), s(5, 10)]);
        assert!((full - 1.0).abs() < 1e-12);
        // [0,10) and [20,30): union 20 over window 30.
        let gap = span_coverage(&[s(0, 10), s(20, 10)]);
        assert!((gap - 20.0 / 30.0).abs() < 1e-12);
    }
}
