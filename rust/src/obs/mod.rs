//! Observability layer: flight-recorder tracing, a unified metrics
//! registry, and leveled logging.
//!
//! Three pieces, all zero-dependency and cheap enough for the hot path:
//!
//! - **[`Tracer`]** — a per-rank, fixed-capacity ring buffer of timed
//!   [`Span`]s (rank/layer/phase/chunk/bytes). Whether a rank records is
//!   decided by a [`TraceMode`] baked into the
//!   [`crate::coordinator::RankState`] at build: `Off` costs two branches
//!   per span site and never allocates, `On` overwrites the oldest span
//!   once the ring fills. [`chrome_trace_json`] renders rank tracks as
//!   Chrome trace-event JSON loadable in Perfetto/`chrome://tracing`.
//! - **[`MetricsRegistry`]** — one snapshotable interface over the
//!   crate's scattered counters (fabric endpoint traffic, engine
//!   [`crate::util::PhaseTimer`] phases, serving-pool stats), rendered
//!   as Prometheus text exposition
//!   ([`crate::serving::RankPool::prometheus`] serves it live).
//! - **[`crate::log!`]** — leveled stderr diagnostics gated by
//!   `SPDNN_LOG` (default `info`; `off` silences tests).
//!
//! Environment contract (see `docs/OBSERVABILITY.md`): `SPDNN_TRACE`
//! turns env-driven tracing on (`1`/`on`, or a number = ring capacity);
//! `SPDNN_LOG` picks the log level. Both are parsed once per process.

pub mod log;
pub mod metrics;
pub mod trace;

pub use self::log::{log_enabled, LogLevel};
pub use metrics::MetricsRegistry;
pub use trace::{
    chrome_trace_json, span_coverage, Span, TraceMode, Tracer, DEFAULT_TRACE_CAPACITY, NO_CHUNK,
    NO_LAYER,
};
