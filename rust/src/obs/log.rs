//! Leveled, structured diagnostics gated by `SPDNN_LOG`.
//!
//! The [`crate::log!`] macro (re-exported as `obs::log!`) replaces the
//! scattered `eprintln!` diagnostics: every line is prefixed with
//! `[spdnn:<level>]` and the whole channel can be silenced with
//! `SPDNN_LOG=off` (useful in tests) or widened with `SPDNN_LOG=debug`.
//! The default level is `info`, matching the output the crate printed
//! before the macro existed.

/// Severity of a [`crate::log!`] line, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum LogLevel {
    /// Unrecoverable or data-losing conditions.
    Error,
    /// Degraded but self-healing conditions (e.g. generation respawn).
    Warn,
    /// Progress notes previously printed unconditionally.
    Info,
    /// High-volume detail (phase profiles), off by default.
    Debug,
}

impl LogLevel {
    /// Short lowercase label used in the line prefix.
    pub fn label(&self) -> &'static str {
        match self {
            LogLevel::Error => "error",
            LogLevel::Warn => "warn",
            LogLevel::Info => "info",
            LogLevel::Debug => "debug",
        }
    }

    fn rank(&self) -> u8 {
        match self {
            LogLevel::Error => 1,
            LogLevel::Warn => 2,
            LogLevel::Info => 3,
            LogLevel::Debug => 4,
        }
    }
}

/// Maximum enabled severity rank, parsed once from `SPDNN_LOG`:
/// `off`/`none`/`silent` → 0 (everything suppressed), `error`/`warn`/
/// `info`/`debug` → that level and above; unset or unrecognized → `info`.
fn max_rank() -> u8 {
    use std::sync::OnceLock;
    static MAX: OnceLock<u8> = OnceLock::new();
    *MAX.get_or_init(
        || match std::env::var("SPDNN_LOG").ok().as_deref().map(str::trim) {
            Some("off") | Some("none") | Some("silent") | Some("0") => 0,
            Some("error") => LogLevel::Error.rank(),
            Some("warn") => LogLevel::Warn.rank(),
            Some("debug") => LogLevel::Debug.rank(),
            _ => LogLevel::Info.rank(),
        },
    )
}

/// True when a line at `lvl` should be emitted under the current
/// `SPDNN_LOG` setting. Used by [`crate::log!`]; callers can also guard
/// expensive formatting with it directly.
pub fn log_enabled(lvl: LogLevel) -> bool {
    lvl.rank() <= max_rank()
}

/// Leveled diagnostic line to stderr: `log!(Warn, "respawn: {e}")`
/// emits `[spdnn:warn] respawn: ...` unless `SPDNN_LOG` filters it out.
/// Levels are the [`crate::obs::LogLevel`] variant names.
#[macro_export]
macro_rules! log {
    ($lvl:ident, $($arg:tt)*) => {
        if $crate::obs::log_enabled($crate::obs::LogLevel::$lvl) {
            eprintln!(
                "[spdnn:{}] {}",
                $crate::obs::LogLevel::$lvl.label(),
                format_args!($($arg)*)
            );
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_order_is_severity_first() {
        assert!(LogLevel::Error < LogLevel::Warn);
        assert!(LogLevel::Warn < LogLevel::Info);
        assert!(LogLevel::Info < LogLevel::Debug);
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(LogLevel::Error.label(), "error");
        assert_eq!(LogLevel::Debug.label(), "debug");
    }

    #[test]
    fn macro_compiles_at_every_level() {
        // Output (if any) goes to stderr; the point is the expansion.
        crate::log!(Error, "e {}", 1);
        crate::log!(Warn, "w {}", 2);
        crate::log!(Info, "i {}", 3);
        crate::log!(Debug, "d {}", 4);
    }
}
