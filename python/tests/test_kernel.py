"""L1 correctness: Pallas kernel vs pure-jnp oracle.

Hypothesis sweeps shapes (including non-tile-divisible), tile sizes, dtypes
and masks; every case asserts allclose against ref.py. This is the CORE
correctness signal for the kernel layer.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import layer_bwd_ref, layer_fwd_ref, masked_matmul_ref
from compile.kernels.spmm import masked_matmul, matvec

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    b=st.integers(1, 20),
    tm=st.sampled_from([8, 16, 32]),
    tk=st.sampled_from([8, 16, 32]),
    tb=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, b, tm, tk, tb, seed):
    rng = np.random.default_rng(seed)
    w = _rand(rng, m, k)
    x = _rand(rng, k, b)
    out = masked_matmul(w, x, None, tm=tm, tk=tk, tb=tb)
    ref = masked_matmul_ref(w, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


@given(
    m=st.integers(1, 60),
    k=st.integers(1, 60),
    b=st.integers(1, 12),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_matmul_matches_ref(m, k, b, density, seed):
    rng = np.random.default_rng(seed)
    w = _rand(rng, m, k)
    x = _rand(rng, k, b)
    mask = jnp.asarray((rng.random((m, k)) < density).astype(np.float32))
    out = masked_matmul(w, x, mask, tm=16, tk=16, tb=8)
    ref = masked_matmul_ref(w, x, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


@given(
    m=st.integers(1, 80),
    k=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_matches_ref(m, k, seed):
    rng = np.random.default_rng(seed)
    w = _rand(rng, m, k)
    x = _rand(rng, k)
    out = matvec(w, x, tm=32, tk=32)
    ref = masked_matmul_ref(w, x)
    assert out.shape == (m,)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_zero_mask_gives_zero_output():
    rng = np.random.default_rng(1)
    w = _rand(rng, 20, 20)
    x = _rand(rng, 20, 4)
    mask = jnp.zeros((20, 20), dtype=jnp.float32)
    out = masked_matmul(w, x, mask, tm=8, tk=8, tb=4)
    np.testing.assert_allclose(np.asarray(out), 0.0)


def test_identity_mask_equals_unmasked():
    rng = np.random.default_rng(2)
    w = _rand(rng, 33, 17)
    x = _rand(rng, 17, 5)
    ones = jnp.ones((33, 17), dtype=jnp.float32)
    a = masked_matmul(w, x, ones, tm=16, tk=16, tb=4)
    b = masked_matmul(w, x, None, tm=16, tk=16, tb=4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_exact_tile_divisible_shapes():
    rng = np.random.default_rng(3)
    w = _rand(rng, 64, 32)
    x = _rand(rng, 32, 16)
    out = masked_matmul(w, x, None, tm=32, tk=16, tb=16)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(masked_matmul_ref(w, x)), atol=1e-4
    )


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_dtypes(dtype):
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.normal(size=(24, 24)).astype(dtype))
    x = jnp.asarray(rng.normal(size=(24, 3)).astype(dtype))
    out = masked_matmul(w, x, None, tm=8, tk=8, tb=4)
    assert out.dtype == w.dtype
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(masked_matmul_ref(w, x)), atol=1e-4
    )


def test_layer_refs_are_consistent():
    # ref sanity: fwd uses sigmoid; bwd is the transpose product
    rng = np.random.default_rng(5)
    w = _rand(rng, 10, 8)
    x = _rand(rng, 8)
    bias = _rand(rng, 10)
    f = layer_fwd_ref(w, x, bias)
    assert f.shape == (10,)
    assert bool(jnp.all((f > 0) & (f < 1)))
    d = _rand(rng, 10)
    s = layer_bwd_ref(w, d)
    np.testing.assert_allclose(
        np.asarray(s), np.asarray(jnp.matmul(w.T, d)), atol=1e-5
    )


from compile.kernels.spmm import fused_layer
from compile.kernels.ref import layer_fwd_ref as _fwd_ref


@given(
    m=st.integers(1, 60),
    k=st.integers(1, 60),
    b=st.integers(1, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_layer_matches_ref(m, k, b, seed):
    rng = np.random.default_rng(seed)
    w = _rand(rng, m, k)
    x = _rand(rng, k, b)
    bias = _rand(rng, m)
    out = fused_layer(w, x, bias, tm=16, tk=16, tb=8)
    ref = _fwd_ref(w, x, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


@given(m=st.integers(1, 50), k=st.integers(1, 50), seed=st.integers(0, 2**31 - 1))
def test_fused_layer_matvec(m, k, seed):
    rng = np.random.default_rng(seed)
    w, x, bias = _rand(rng, m, k), _rand(rng, k), _rand(rng, m)
    out = fused_layer(w, x, bias, tm=32, tk=16, tb=8)
    assert out.shape == (m,)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(_fwd_ref(w, x, bias)), atol=1e-5, rtol=1e-5
    )


def test_fused_layer_outputs_in_unit_interval():
    rng = np.random.default_rng(6)
    w = _rand(rng, 40, 40) * 5
    x = _rand(rng, 40, 4)
    bias = _rand(rng, 40)
    out = np.asarray(fused_layer(w, x, bias, tm=16, tk=16, tb=4))
    # f32 sigmoid saturates to exactly 0/1 for large |z|
    assert ((out >= 0) & (out <= 1)).all()


from compile.kernels.spmm import matvec_t


@given(
    m=st.integers(1, 60),
    k=st.integers(1, 60),
    b=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_t_matches_transpose(m, k, b, seed):
    rng = np.random.default_rng(seed)
    w = _rand(rng, m, k)
    d = _rand(rng, m, b)
    out = matvec_t(w, d, tm=16, tk=16, tb=4)
    ref = jnp.matmul(w.T, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


@given(m=st.integers(1, 50), k=st.integers(1, 50), seed=st.integers(0, 2**31 - 1))
def test_matvec_t_vector_shape(m, k, seed):
    rng = np.random.default_rng(seed)
    w, d = _rand(rng, m, k), _rand(rng, m)
    out = matvec_t(w, d, tm=32, tk=16, tb=8)
    assert out.shape == (k,)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(jnp.matmul(w.T, d)), atol=1e-4, rtol=1e-4
    )
