"""L2 correctness: model blocks vs refs, shapes, and autodiff consistency."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import layer_bwd_ref, layer_fwd_ref

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@given(m=st.integers(1, 50), k=st.integers(1, 50), seed=st.integers(0, 2**31 - 1))
def test_layer_fwd_matches_ref(m, k, seed):
    rng = np.random.default_rng(seed)
    w, x, b = _rand(rng, m, k), _rand(rng, k), _rand(rng, m)
    out = model.layer_fwd(w, x, b)
    ref = layer_fwd_ref(w, x, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


@given(m=st.integers(1, 50), k=st.integers(1, 50), seed=st.integers(0, 2**31 - 1))
def test_layer_bwd_matches_ref(m, k, seed):
    rng = np.random.default_rng(seed)
    w, d = _rand(rng, m, k), _rand(rng, m)
    out = model.layer_bwd(w, d)
    ref = layer_bwd_ref(w, d)
    assert out.shape == (k,)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    b=st.integers(1, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_layer_fwd_batch_matches_per_column(m, k, b, seed):
    rng = np.random.default_rng(seed)
    w, x, bias = _rand(rng, m, k), _rand(rng, k, b), _rand(rng, m)
    out = model.layer_fwd_batch(w, x, bias)
    assert out.shape == (m, b)
    for j in range(b):
        single = model.layer_fwd(w, x[:, j], bias)
        np.testing.assert_allclose(
            np.asarray(out[:, j]), np.asarray(single), atol=1e-5, rtol=1e-5
        )


def test_bwd_is_jax_vjp_of_pre_activation():
    # s = Wᵀδ is exactly the VJP of z = Wx w.r.t. x with cotangent δ.
    rng = np.random.default_rng(7)
    w, x, d = _rand(rng, 12, 9), _rand(rng, 9), _rand(rng, 12)
    _, vjp = jax.vjp(lambda xv: jnp.matmul(w, xv), x)
    (expected,) = vjp(d)
    got = model.layer_bwd(w, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expected), atol=1e-4)


def test_train_block_returns_both():
    rng = np.random.default_rng(8)
    w, x, bias, d = _rand(rng, 6, 5), _rand(rng, 5), _rand(rng, 6), _rand(rng, 6)
    xo, s = model.layer_train_block(w, x, bias, d)
    np.testing.assert_allclose(
        np.asarray(xo), np.asarray(model.layer_fwd(w, x, bias)), atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(s), np.asarray(model.layer_bwd(w, d)), atol=1e-6
    )


def test_sigmoid_range():
    z = jnp.asarray([-100.0, 0.0, 100.0], dtype=jnp.float32)
    s = model.sigmoid(z)
    assert float(s[0]) < 1e-6
    assert abs(float(s[1]) - 0.5) < 1e-6
    assert float(s[2]) > 1 - 1e-6
