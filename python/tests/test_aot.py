"""AOT path: lowered HLO text is well-formed and parameterized correctly."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot


def test_lower_fwd_produces_hlo_text():
    txt = aot.lower_fwd(8, 16)
    assert "HloModule" in txt
    # parameters: W [8,16], x [16], b [8]
    assert "f32[8,16]" in txt
    assert "f32[16]" in txt.replace(" ", "")


def test_lower_bwd_produces_hlo_text():
    txt = aot.lower_bwd(8, 16)
    assert "HloModule" in txt
    assert "f32[8,16]" in txt


def test_lower_fwd_batch_shapes():
    txt = aot.lower_fwd_batch(8, 16, 4)
    assert "HloModule" in txt
    assert "f32[16,4]" in txt.replace(" ", "")


def test_parse_shapes():
    assert aot.parse_shapes("64x256,256x256") == [(64, 256), (256, 256)]
    assert aot.parse_shapes(" 8x8 ") == [(8, 8)]
    assert aot.parse_shapes("") == []


def test_cli_writes_artifacts_and_manifest(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--shapes",
            "8x16",
            "--batch",
            "4",
        ],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["fwd"]["8x16"] == "layer_fwd_8x16.hlo.txt"
    for section in ("fwd", "bwd", "fwd_batch"):
        for fname in manifest[section].values():
            txt = (out / fname).read_text()
            assert "HloModule" in txt, fname


@pytest.mark.parametrize("m,k", [(1, 1), (3, 7), (64, 256)])
def test_various_shapes_lower(m, k):
    assert "HloModule" in aot.lower_fwd(m, k)
