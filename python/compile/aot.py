"""AOT lowering: JAX (L2, calling the L1 Pallas kernel) → HLO text.

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts \
        --shapes 64x256,256x256 --batch 64

Artifacts written:
    layer_fwd_{m}x{k}.hlo.txt          σ(Wx + b)        (W[m,k], x[k], b[m])
    layer_bwd_{m}x{k}.hlo.txt          Wᵀδ              (W[m,k], δ[m])
    layer_fwd_batch_{m}x{k}x{b}.hlo.txt σ(WX + b)       (W[m,k], X[k,b], b[m])
    manifest.json                      shape → file map
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_fwd(m, k):
    fn = lambda w, x, b: (model.layer_fwd(w, x, b),)
    return to_hlo_text(jax.jit(fn).lower(spec(m, k), spec(k), spec(m)))


def lower_bwd(m, k):
    fn = lambda w, d: (model.layer_bwd(w, d),)
    return to_hlo_text(jax.jit(fn).lower(spec(m, k), spec(m)))


def lower_fwd_batch(m, k, b):
    fn = lambda w, x, bias: (model.layer_fwd_batch(w, x, bias),)
    return to_hlo_text(jax.jit(fn).lower(spec(m, k), spec(k, b), spec(m)))


def parse_shapes(s):
    out = []
    for part in s.split(","):
        part = part.strip()
        if not part:
            continue
        m, k = part.split("x")
        out.append((int(m), int(k)))
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--shapes",
        default="64x256",
        help="comma-separated m x k row-block shapes, e.g. 64x256,256x256",
    )
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"fwd": {}, "bwd": {}, "fwd_batch": {}}

    for m, k in parse_shapes(args.shapes):
        name = f"layer_fwd_{m}x{k}.hlo.txt"
        with open(os.path.join(args.out_dir, name), "w") as f:
            f.write(lower_fwd(m, k))
        manifest["fwd"][f"{m}x{k}"] = name
        print(f"wrote {name}")

        name = f"layer_bwd_{m}x{k}.hlo.txt"
        with open(os.path.join(args.out_dir, name), "w") as f:
            f.write(lower_bwd(m, k))
        manifest["bwd"][f"{m}x{k}"] = name
        print(f"wrote {name}")

        name = f"layer_fwd_batch_{m}x{k}x{args.batch}.hlo.txt"
        with open(os.path.join(args.out_dir, name), "w") as f:
            f.write(lower_fwd_batch(m, k, args.batch))
        manifest["fwd_batch"][f"{m}x{k}x{args.batch}"] = name
        print(f"wrote {name}")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({args.out_dir})")


if __name__ == "__main__":
    main()
