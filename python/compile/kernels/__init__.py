"""L1: Pallas kernels for the paper's compute hot-spot."""
