"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth)."""

import jax.numpy as jnp


def masked_matmul_ref(w, x, mask=None):
    """(W ⊙ mask) @ X — reference for the blocked masked matmul kernel.

    w: [m, k]; x: [k, b] or [k]; mask: [m, k] or None.
    """
    wm = w if mask is None else w * mask
    return jnp.matmul(wm, x)


def sigmoid(z):
    return 1.0 / (1.0 + jnp.exp(-z))


def layer_fwd_ref(w, x, bias, mask=None):
    """sigmoid(W x + b): the rank-local forward block (Alg. 2 lines 6, 10)."""
    z = masked_matmul_ref(w, x, mask)
    if bias is not None:
        z = z + bias if z.ndim == 1 else z + bias[:, None]
    return sigmoid(z)


def layer_bwd_ref(w, delta, mask=None):
    """s = Wᵀ δ: the rank-local backward product (Alg. 3 line 4)."""
    wm = w if mask is None else w * mask
    return jnp.matmul(wm.T, delta)
