"""L1 — the SpMV/SpMM hot-spot as a Pallas blocked masked-matmul kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's hot loop
is a CPU CSR SpMV; the TPU-idiomatic form of the same computation is a
*masked dense tile* matmul so the MXU systolic array does the work:

- BlockSpec tiles the weight row-block ``(TM, TK)`` and the activations
  ``(TK, TB)`` through VMEM — the HBM→VMEM schedule that replaces the
  paper's cache blocking;
- a 0/1 mask (the sparsity pattern) multiplies into the weight tile before
  the ``jnp.dot`` so pruned connections contribute exact zeros;
- the K-reduction runs over the innermost grid axis into a VMEM
  accumulator, revisiting the same output tile (standard Pallas matmul
  pattern).

``interpret=True`` everywhere: real-TPU lowering emits Mosaic custom-calls
the CPU PJRT client cannot execute. VMEM/MXU estimates for the real-TPU
deployment are documented in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: 128 matches the MXU systolic dimension; f32 tiles of
# 3 x 128x128 x 4B ≈ 192 KiB sit comfortably in a TPU core's ~16 MiB VMEM
# with room for double buffering.
TM, TK, TB = 128, 128, 128


def _matmul_kernel(w_ref, x_ref, o_ref, *, nk):
    """One (mi, bi, ki) grid step: o[mi, bi] += w[mi, ki] @ x[ki, bi]."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        w_ref[...], x_ref[...], preferred_element_type=o_ref.dtype
    )


def _masked_matmul_kernel(w_ref, m_ref, x_ref, o_ref, *, nk):
    """Masked variant: the sparsity pattern zeroes the weight tile first."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    wt = w_ref[...] * m_ref[...].astype(w_ref.dtype)
    o_ref[...] += jnp.dot(wt, x_ref[...], preferred_element_type=o_ref.dtype)


def _pad_to(a, rows, cols):
    pr, pc = rows - a.shape[0], cols - a.shape[1]
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


def _ceil_to(n, t):
    return ((n + t - 1) // t) * t


@functools.partial(jax.jit, static_argnames=("tm", "tk", "tb"))
def masked_matmul(w, x, mask=None, *, tm=TM, tk=TK, tb=TB):
    """(W ⊙ mask) @ X via the Pallas kernel. Shapes need not divide the
    tiles — inputs are zero-padded and the result sliced back.

    w: [m, k] f32; x: [k, b] (or [k] → matvec); mask: [m, k] or None.
    """
    vec = x.ndim == 1
    if vec:
        x = x[:, None]
    m, k = w.shape
    b = x.shape[1]
    mp, kp, bp = _ceil_to(m, tm), _ceil_to(k, tk), _ceil_to(b, tb)
    wp = _pad_to(w, mp, kp)
    xp = _pad_to(x, kp, bp)
    grid = (mp // tm, bp // tb, kp // tk)

    w_spec = pl.BlockSpec((tm, tk), lambda mi, bi, ki: (mi, ki))
    x_spec = pl.BlockSpec((tk, tb), lambda mi, bi, ki: (ki, bi))
    o_spec = pl.BlockSpec((tm, tb), lambda mi, bi, ki: (mi, bi))

    if mask is None:
        out = pl.pallas_call(
            functools.partial(_matmul_kernel, nk=grid[2]),
            grid=grid,
            in_specs=[w_spec, x_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((mp, bp), w.dtype),
            interpret=True,
        )(wp, xp)
    else:
        mkp = _pad_to(mask.astype(w.dtype), mp, kp)
        out = pl.pallas_call(
            functools.partial(_masked_matmul_kernel, nk=grid[2]),
            grid=grid,
            in_specs=[w_spec, w_spec, x_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((mp, bp), w.dtype),
            interpret=True,
        )(wp, mkp, xp)

    out = out[:m, :b]
    return out[:, 0] if vec else out


def matvec(w, x, *, tm=TM, tk=TK):
    """W @ x for a dense-with-zeros row block (the SpMV of Alg. 2 line 6)."""
    return masked_matmul(w, x, None, tm=tm, tk=tk, tb=TB)


def _fused_layer_kernel(w_ref, x_ref, b_ref, o_ref, *, nk):
    """Fused σ(Wx + b): accumulate over K tiles, epilogue on the last one.

    The epilogue (bias add + sigmoid) runs inside the kernel while the
    output tile is still resident in VMEM — on a real TPU this saves an
    HBM round-trip per layer compared to matmul-then-elementwise.
    """
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(w_ref[...], x_ref[...], preferred_element_type=o_ref.dtype)

    @pl.when(ki == nk - 1)
    def _epilogue():
        z = o_ref[...] + b_ref[...][:, None]
        o_ref[...] = 1.0 / (1.0 + jnp.exp(-z))


@functools.partial(jax.jit, static_argnames=("tm", "tk", "tb"))
def fused_layer(w, x, bias, *, tm=TM, tk=TK, tb=TB):
    """σ(W @ X + b) in one Pallas kernel (fused epilogue).

    w: [m, k]; x: [k, b] or [k]; bias: [m].
    """
    vec = x.ndim == 1
    if vec:
        x = x[:, None]
    m, k = w.shape
    b = x.shape[1]
    mp, kp, bp = _ceil_to(m, tm), _ceil_to(k, tk), _ceil_to(b, tb)
    wp = _pad_to(w, mp, kp)
    xp = _pad_to(x, kp, bp)
    bzp = jnp.pad(bias, (0, mp - m))
    grid = (mp // tm, bp // tb, kp // tk)
    out = pl.pallas_call(
        functools.partial(_fused_layer_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda mi, bi, ki: (mi, ki)),
            pl.BlockSpec((tk, tb), lambda mi, bi, ki: (ki, bi)),
            pl.BlockSpec((tm,), lambda mi, bi, ki: (mi,)),
        ],
        out_specs=pl.BlockSpec((tm, tb), lambda mi, bi, ki: (mi, bi)),
        out_shape=jax.ShapeDtypeStruct((mp, bp), w.dtype),
        interpret=True,
    )(wp, xp, bzp)
    out = out[:m, :b]
    return out[:, 0] if vec else out


def _matmul_t_kernel(w_ref, d_ref, o_ref, *, nm):
    """Transpose-product step: o[ki, bi] += W[mi, ki]ᵀ @ d[mi, bi].

    Reads the *untransposed* weight tile and transposes in-register — the
    backward pass (Alg. 3 line 4) then shares the exact HBM layout of the
    forward weights (no materialized Wᵀ, halving weight memory traffic per
    training step on a real TPU).
    """
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        w_ref[...].T, d_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("tm", "tk", "tb"))
def matvec_t(w, d, *, tm=TM, tk=TK, tb=TB):
    """s = Wᵀ @ d via the transposed-tile kernel.

    w: [m, k]; d: [m] or [m, b] → s: [k] or [k, b].
    """
    vec = d.ndim == 1
    if vec:
        d = d[:, None]
    m, k = w.shape
    b = d.shape[1]
    mp, kp, bp = _ceil_to(m, tm), _ceil_to(k, tk), _ceil_to(b, tb)
    wp = _pad_to(w, mp, kp)
    dp = _pad_to(d, mp, bp)
    # grid: (k tiles, b tiles, m reduction)
    grid = (kp // tk, bp // tb, mp // tm)
    out = pl.pallas_call(
        functools.partial(_matmul_t_kernel, nm=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda ki, bi, mi: (mi, ki)),
            pl.BlockSpec((tm, tb), lambda ki, bi, mi: (mi, bi)),
        ],
        out_specs=pl.BlockSpec((tk, tb), lambda ki, bi, mi: (ki, bi)),
        out_shape=jax.ShapeDtypeStruct((kp, bp), w.dtype),
        interpret=True,
    )(wp, dp)
    out = out[:k, :b]
    return out[:, 0] if vec else out
