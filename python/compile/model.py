"""L2 — the rank-local layer computation in JAX, calling the L1 kernel.

These are the compute blocks the Rust coordinator executes per layer per
rank (Alg. 2 line 6 + 10 forward; Alg. 3 line 4 backward). They are written
over *dense-with-zeros* row blocks (the TPU-idiomatic masked form, see
kernels/spmm.py) and AOT-lowered by aot.py to HLO text, one artifact per
(rows × cols [× batch]) shape variant. Python never runs at serving time.
"""

import jax.numpy as jnp

from .kernels import spmm


def sigmoid(z):
    return 1.0 / (1.0 + jnp.exp(-z))


def layer_fwd(w, x, bias):
    """x^k = σ(W_blk · x^{k-1} + b): the rank-local forward block.

    Uses the fused Pallas kernel (matmul + bias + sigmoid epilogue in one
    VMEM-resident pass). w: [m, k] dense-with-zeros; x: [k]; bias: [m].
    """
    return spmm.fused_layer(w, x, bias)


def layer_fwd_batch(w, x, bias):
    """Batched variant (minibatch SpMM, §5.1). x: [k, b] → [m, b]."""
    return spmm.fused_layer(w, x, bias)


def layer_bwd(w, delta):
    """s = W_blkᵀ · δ: the rank-local backward product (Alg. 3 line 4).

    w: [m, k]; delta: [m] → s: [k]. Uses the transposed-tile Pallas kernel
    (in-register tile transpose — shares the forward weight layout, no
    materialized Wᵀ; row partition of W == column partition of Wᵀ).
    """
    return spmm.matvec_t(w, delta)


def layer_train_block(w, x, bias, delta):
    """Fused forward+backward building block used by the training artifact:
    returns (x_out, s). Keeping both in one HLO module lets XLA share the
    masked tiles between the two products."""
    return layer_fwd(w, x, bias), layer_bwd(w, delta)
